"""Replicated serving cluster: health model, prefix-affinity routing,
cross-replica failover (image migration vs. restart), drain/rejoin, and
the typed ReplicaLost dead-letter path.  The cross-cutting invariant in
every end-to-end test: cluster tokens are bit-identical to the same
requests served by one engine run (greedy decode is deterministic and
batch-invariant, so routing and failover must never show up in the
output stream)."""

import numpy as np
import jax
import pytest

from repro.serving import (FaultPlan, HealthPolicy, PagedCacheConfig,
                           PagedServingEngine, RecoveryPolicy,
                           ReplicaLost, Request, RequestFailed,
                           ServingCluster, TenantConfig)
from repro.serving.cluster import DEAD, DOWN, HEALTHY, SUSPECT

_C = {}


def _cluster_fixture():
    """One compiled engine shared by every test in the file (replicas
    multiply run-state, not compilations)."""
    if not _C:
        from repro.configs.registry import get_config
        from repro.models.api import build_model
        cfg = get_config("qwen2_7b", smoke=True)
        model = build_model(cfg)
        pcfg = PagedCacheConfig(page_size=8, n_pages=24, max_slots=4,
                                max_blocks=6, segment_len=4,
                                retain_pages=4)
        eng = PagedServingEngine(
            model, pcfg, tenants=[TenantConfig("a"), TenantConfig("b")])
        _C["x"] = (cfg, model.init(jax.random.PRNGKey(0)), eng)
    return _C["x"]


def _mk_reqs(cfg, n=6, gen=12):
    from repro.data.synthetic import lm_tokens
    return [Request(rid=i, prompt=np.asarray(
                lm_tokens(16, cfg.vocab_size, seed=40 + i)
            ).astype(np.int32), max_new_tokens=gen,
            tenant="a" if i % 2 else "b") for i in range(n)]


def _baseline(cfg, params, eng):
    if "base" not in _C:
        reqs = _mk_reqs(cfg)
        eng.run(reqs, params)
        _C["base"] = {r.rid: list(r.tokens) for r in reqs}
    return _C["base"]


def _assert_pools_drained(cl):
    """Survivor invariant: every non-fenced replica's pool back to
    free + retention pins, ledger intact — failover leaked nothing."""
    for rep in cl.replicas:
        if rep.fenced:
            continue
        s = rep.run.sched.rm.stats()
        assert s["free_pages"] + s["pinned_pages"] \
            == rep.run.pcfg.allocatable_pages, (rep.name, s)
        assert s["held_pages"] == s["pinned_pages"], (rep.name, s)


# ------------------------------------------------------------ unit level
class TestHealthPolicy:
    def test_validation(self):
        with pytest.raises(ValueError):
            HealthPolicy(suspect_after=0)
        with pytest.raises(ValueError):
            HealthPolicy(suspect_after=5, dead_after=4)

    def test_replica_lost_record_is_typed_and_structured(self):
        f = ReplicaLost(rid=7, tenant="a", reason="gone", boundary=3,
                        retries=2, site="replica_crash", ckpt_tokens=5,
                        replica="r1")
        assert isinstance(f, RequestFailed)
        rec = f.record()
        assert rec["replica"] == "r1" and rec["site"] == "replica_crash"
        assert rec["ckpt_tokens"] == 5


# ------------------------------------------------------------ end to end
def test_fault_free_cluster_bit_identical_to_single_engine():
    """Routing across 3 replicas is invisible in the token streams, and
    the front door actually spread the load."""
    cfg, params, eng = _cluster_fixture()
    base = _baseline(cfg, params, eng)
    cl = ServingCluster(eng, params, n_replicas=3)
    reqs = _mk_reqs(cfg)
    out = cl.run(reqs)
    assert out["n_finished"] == len(reqs)
    assert out["n_dead_lettered"] == 0
    assert {r.rid: list(r.tokens) for r in reqs} == base
    stepped = [v["n_segments"] for v in out["replicas"].values()]
    assert sum(1 for s in stepped if s) >= 2    # load actually spread
    _assert_pools_drained(cl)


def test_replica_crash_mid_burst_recovers_bit_identical():
    cfg, params, eng = _cluster_fixture()
    base = _baseline(cfg, params, eng)
    cl = ServingCluster(eng, params, n_replicas=3,
                        faults=FaultPlan.at(replica_crash=1))
    reqs = _mk_reqs(cfg)
    out = cl.run(reqs)
    assert out["faults"]["fired"] == [["replica_crash", 1]]
    assert sum(1 for v in out["replicas"].values()
               if v["state"] == DEAD) == 1
    assert out["n_finished"] + out["n_dead_lettered"] == len(reqs)
    for r in reqs:
        if r.failure is None:
            assert list(r.tokens) == base[r.rid]
        else:
            assert isinstance(r.failure, ReplicaLost)
    _assert_pools_drained(cl)


def test_replica_hang_detected_and_failed_over():
    """A hang (host loop wedged, nothing destroyed) is indistinguishable
    from a crash to the heartbeat model and takes the same salvage
    path."""
    cfg, params, eng = _cluster_fixture()
    base = _baseline(cfg, params, eng)
    cl = ServingCluster(eng, params, n_replicas=3,
                        faults=FaultPlan.at(replica_hang=2))
    reqs = _mk_reqs(cfg)
    out = cl.run(reqs)
    dead = [r for r in cl.replicas if r.state == DEAD]
    assert len(dead) == 1 and dead[0].cause == "replica_hang"
    assert out["n_finished"] + out["n_dead_lettered"] == len(reqs)
    for r in reqs:
        if r.failure is None:
            assert list(r.tokens) == base[r.rid]
    _assert_pools_drained(cl)


def test_heartbeat_loss_is_transient_suspect_not_death():
    """One dropped heartbeat with stepping intact never kills a replica:
    it may dip to SUSPECT and must recover to HEALTHY on the next beat
    (the false-positive resilience the thresholds buy)."""
    cfg, params, eng = _cluster_fixture()
    base = _baseline(cfg, params, eng)
    cl = ServingCluster(eng, params, n_replicas=2,
                        faults=FaultPlan.at(heartbeat_loss=0),
                        health=HealthPolicy(suspect_after=1,
                                            dead_after=4))
    reqs = _mk_reqs(cfg)
    out = cl.run(reqs)
    assert out["n_finished"] == len(reqs)
    assert out["n_dead_lettered"] == 0
    assert all(v["state"] == HEALTHY
               for v in out["replicas"].values())
    assert {r.rid: list(r.tokens) for r in reqs} == base
    _assert_pools_drained(cl)


def test_drain_and_rejoin_rolling_restart():
    """Graceful drain migrates everything out with zero retries burned,
    the replica rejoins with a cold trie, and the tokens never notice."""
    cfg, params, eng = _cluster_fixture()
    base = _baseline(cfg, params, eng)
    cl = ServingCluster(eng, params, n_replicas=3)
    reqs = _mk_reqs(cfg)
    seen = {}

    def hook(c, rnd):
        if rnd == 1:
            seen["moved"] = c.drain("r0")
            assert c._replica("r0").state == DOWN
        if rnd == 2:
            c.rejoin("r0")
            assert c._replica("r0").state == HEALTHY

    out = cl.run(reqs, on_round=hook)
    assert seen["moved"] >= 1 and out["n_drained"] == seen["moved"]
    assert out["n_finished"] == len(reqs)
    assert out["n_dead_lettered"] == 0
    assert all(r.n_retries == 0 for r in reqs)   # drain is free
    assert {r.rid: list(r.tokens) for r in reqs} == base
    # the rejoined replica is live and serves a follow-up wave
    assert cl._replica("r0").live
    wave2 = _mk_reqs(cfg)
    out2 = cl.run(wave2)
    assert out2["n_finished"] == out["n_finished"] + len(wave2)
    assert {r.rid: list(r.tokens) for r in wave2} == base
    _assert_pools_drained(cl)


def test_exhausted_retries_dead_letter_typed_replica_lost():
    """With zero retries allowed, in-flight work lost to a replica death
    dead-letters as ReplicaLost naming the site and replica, while the
    untouched replica's requests finish bit-identical."""
    cfg, params, eng = _cluster_fixture()
    base = _baseline(cfg, params, eng)
    cl = ServingCluster(eng, params, n_replicas=2,
                        recovery=RecoveryPolicy(max_retries=0))
    reqs = _mk_reqs(cfg)

    def hook(c, rnd):
        if rnd == 2:
            c.kill("r0")

    out = cl.run(reqs, on_round=hook)
    lost = [r for r in reqs if r.failure is not None]
    assert lost and out["n_dead_lettered"] == len(lost)
    for r in lost:
        assert isinstance(r.failure, ReplicaLost)
        assert r.failure.site == "replica_crash"
        assert r.failure.replica == "r0"
    recs = out["dead_letter_records"]
    assert len(recs) == len(lost)
    assert all(rec["replica"] == "r0" for rec in recs)
    for r in reqs:
        if r.failure is None:
            assert list(r.tokens) == base[r.rid]
    _assert_pools_drained(cl)


def test_prefix_affinity_routes_to_warm_replica():
    """A repeated prompt routes to the replica whose retained trie pages
    already hold it — the second wave is an affinity hit."""
    from repro.data.synthetic import lm_tokens
    cfg, params, eng = _cluster_fixture()
    shared = np.asarray(lm_tokens(16, cfg.vocab_size, seed=99)
                        ).astype(np.int32)
    cl = ServingCluster(eng, params, n_replicas=3)
    cl.run([Request(rid="w", prompt=shared.copy(), max_new_tokens=4,
                    tenant="a")])
    cl.run([Request(rid="x", prompt=shared.copy(), max_new_tokens=4,
                    tenant="a")])
    fd = cl.front_door.stats()
    assert fd["routed"] == 2 and fd["affinity_hits"] >= 1


def test_all_replicas_lost_dead_letters_everything():
    """No survivors: every request ends in a typed ReplicaLost (none
    lost silently, the run still terminates)."""
    cfg, params, eng = _cluster_fixture()
    cl = ServingCluster(eng, params, n_replicas=2)
    reqs = _mk_reqs(cfg, n=4)

    def hook(c, rnd):
        if rnd == 1:
            c.kill("r0")
            c.kill("r1")

    out = cl.run(reqs, on_round=hook)
    assert out["n_finished"] + out["n_dead_lettered"] == len(reqs)
    assert all(r.failure is None or isinstance(r.failure, ReplicaLost)
               for r in reqs)
    assert all(r.t_done is not None for r in reqs)


def test_engine_seeded_chaos_cluster_survives():
    """Seeded chaos over BOTH engine and replica sites at once: the
    cluster terminates with every request bit-identical or typed-dead-
    lettered and no survivor leaks a page."""
    cfg, params, eng = _cluster_fixture()
    base = _baseline(cfg, params, eng)
    plan = FaultPlan.seeded(11, rate=0.15, max_fires=2)
    cl = ServingCluster(eng, params, n_replicas=3, faults=plan)
    reqs = _mk_reqs(cfg)
    out = cl.run(reqs)
    assert out["n_finished"] + out["n_dead_lettered"] == len(reqs)
    for r in reqs:
        if r.failure is None:
            assert list(r.tokens) == base[r.rid], \
                f"rid {r.rid} diverged after faults {plan.log}"
    _assert_pools_drained(cl)
