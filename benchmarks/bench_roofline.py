"""Roofline table emission: reads the dry-run sweep results
(benchmarks/results/dryrun_*.json produced by repro.launch.dryrun) and
prints the §Roofline rows.  One row per (arch x shape x mesh)."""

from __future__ import annotations

import json
import os

try:
    from benchmarks.common import RESULTS_DIR, emit
except ImportError:
    from common import RESULTS_DIR, emit


def rows(path: str):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def main():
    n = 0
    for suffix in ("singlepod", "multipod"):
        for r in rows(os.path.join(RESULTS_DIR, f"dryrun_{suffix}.json")):
            name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
            if r["status"] != "ok":
                emit(name, 0.0, f"{r['status']}:{r.get('reason','')[:40]}")
                continue
            rf = r["roofline"]
            emit(name, rf["bound_s"] * 1e6,
                 f"dom={rf['dominant'][:-2]};"
                 f"comp_ms={rf['compute_s']*1e3:.2f};"
                 f"mem_ms={rf['memory_s']*1e3:.2f};"
                 f"coll_ms={rf['collective_s']*1e3:.2f};"
                 f"useful={rf.get('useful_flops_fraction', 0):.3f}")
            n += 1
    if n == 0:
        emit("roofline_missing", 0.0,
             "run: python -m repro.launch.dryrun --all [--multi-pod]")
    return n


if __name__ == "__main__":
    main()
