"""Paper Fig. 5: combined strategies and O-task ORDER sensitivity.

(a) scaling-then-pruning: the optimal pruning rate drops vs pruning alone
    (the preceding scaling removed redundancy);
(b) pruning-then-scaling: a different trade-off point.

Emits per-order final (accuracy, rate, scale, resources) rows.
"""

from __future__ import annotations

from repro.core.metamodel import MetaModel
from repro.core.strategies import combined_strategy, pruning_strategy

try:
    from benchmarks.common import emit, save_json
except ImportError:
    from common import emit, save_json

CFG = {"ModelGen.train_samples": 2048, "ModelGen.train_epochs": 4,
       "Pruning.train_epochs": 2, "Scaling.train_epochs": 3,
       "Scaling.max_trials_num": 2, "Scaling.tolerate_acc_loss": 0.02}


def final_metrics(meta: MetaModel) -> dict:
    art = meta.latest("dnn")
    p = meta.get("pruning.result") or {}
    s = meta.get("scaling.result") or {}
    return {"accuracy": art.metrics.get("accuracy"),
            "pruning_rate": p.get("pruning_rate"),
            "scale": s.get("scale", 1.0),
            "macs_fraction": art.metrics.get("macs_fraction"),
            "weight_bits": art.metrics.get("weight_bits")}


def main(model: str = "jet_dnn"):
    results = {}
    # single-task baseline (pruning alone)
    meta = pruning_strategy(model, train_epochs=2).execute(
        MetaModel(dict(CFG)))
    results["P"] = final_metrics(meta)

    for order in ("SP", "PS", "SPQ", "PSQ"):
        meta = combined_strategy(model, order).execute(MetaModel(dict(CFG)))
        results[order] = final_metrics(meta)

    for order, m in results.items():
        emit(f"fig5_{model}_{order}", 0.0,
             f"acc={m['accuracy']:.4f};rate={m['pruning_rate']};"
             f"scale={m['scale']};bits={m['weight_bits']:.0f}")

    # the paper's observation: rate(after scaling) != rate(alone)
    if results["P"]["pruning_rate"] and results["SP"]["pruning_rate"]:
        emit(f"fig5_{model}_order_effect", 0.0,
             f"rate_alone={results['P']['pruning_rate']:.3f};"
             f"rate_after_scaling={results['SP']['pruning_rate']:.3f}")
    save_json("combined_strategies.json", {model: results})
    return results


if __name__ == "__main__":
    main()
