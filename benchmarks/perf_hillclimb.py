"""§Perf hillclimb driver — hypothesis → change → measure → validate.

Three pairs (chosen per the §Perf selection rule from the corrected
baseline table):
  A. qwen1.5-110b x train_4k   — worst roofline bound, memory-dominated,
                                 does not fit HBM at baseline.
  B. deepseek-v2-236b x train_4k — most collective-bound (MoE all-to-all +
                                 FSDP gathers).
  C. granite-moe-1b-a400m x train_4k — driven through the paper's own
                                 machinery: the SHARDING-SEARCH O-task +
                                 QUANTIZATION policy, i.e. MetaML doing
                                 the hillclimb.

Each step is applied CUMULATIVELY when it confirms (keep) and reverted
when it refutes, mirroring the per-iteration methodology.  Results land in
benchmarks/results/perf_hillclimb.json; EXPERIMENTS.md §Perf narrates them.

Run:  PYTHONPATH=src python -m benchmarks.perf_hillclimb [--pair A|B|C]
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import copy
import json
import time

from repro.configs.base import SHAPES
from repro.launch.dryrun import (_cell_model_flops, lower_cell,
                                 probe_layer_costs)
from repro.launch.roofline import HW, roofline

try:
    from benchmarks.common import RESULTS_DIR
except ImportError:
    from common import RESULTS_DIR


def measure(arch: str, shape_name: str, kw: dict) -> dict:
    shape = SHAPES[shape_name]
    t0 = time.time()
    lowered, mesh, model, aux = lower_cell(arch, shape, **kw)
    compiled = lowered.compile()
    corrected = probe_layer_costs(arch, shape, **kw)
    r = roofline(compiled, mesh,
                 model_flops=_cell_model_flops(arch, shape),
                 corrected=corrected)
    r["wall_s"] = time.time() - t0
    r["fallbacks"] = aux["fallbacks"]
    return r


def fmt(r: dict) -> str:
    mem = r.get("memory", {})
    return (f"bound={r['bound_s']*1e3:8.1f}ms dom={r['dominant'][:-2]:10s} "
            f"comp={r['compute_s']*1e3:7.1f} mem={r['memory_s']*1e3:8.1f} "
            f"coll={r['collective_s']*1e3:7.1f} "
            f"peak={mem.get('peak_bytes', 0)/1e9:6.1f}GB "
            f"fits={r.get('fits_hbm')}")


def score(r: dict) -> float:
    """Objective: roofline bound + heavy penalty for not fitting HBM."""
    s = r["bound_s"]
    peak = r.get("memory", {}).get("peak_bytes", 0)
    if peak > HW["hbm_bytes"]:
        s += 10.0 * (peak / HW["hbm_bytes"] - 1.0)
    return s


def run_pair(arch: str, shape: str, base_kw: dict, steps: list) -> dict:
    print(f"\n=== {arch} x {shape} ===", flush=True)
    incumbent = copy.deepcopy(base_kw)
    try:
        base = measure(arch, shape, incumbent)
    except Exception as e:  # noqa: BLE001
        print(f"  baseline ERROR: {e}")
        return {"arch": arch, "shape": shape, "error": repr(e)}
    print(f"  baseline: {fmt(base)}", flush=True)
    log = [{"step": "baseline", "hypothesis": "paper-faithful defaults",
            "config": copy.deepcopy(incumbent), "roofline": base,
            "verdict": "-"}]
    cur = base
    for label, hypothesis, delta in steps:
        trial = copy.deepcopy(incumbent)
        for k, v in delta.items():
            if k == "cfg_overrides":
                trial.setdefault("cfg_overrides", {})
                trial["cfg_overrides"].update(v)
            else:
                trial[k] = v
        try:
            r = measure(arch, shape, trial)
        except Exception as e:  # noqa: BLE001
            print(f"  {label}: ERROR {e}")
            log.append({"step": label, "hypothesis": hypothesis,
                        "config": trial, "error": repr(e),
                        "verdict": "error"})
            continue
        keep = score(r) < score(cur)
        verdict = "confirmed" if keep else "refuted"
        print(f"  {label}: {fmt(r)}  [{verdict}]", flush=True)
        log.append({"step": label, "hypothesis": hypothesis,
                    "config": copy.deepcopy(trial), "roofline": r,
                    "verdict": verdict})
        if keep:
            incumbent, cur = trial, r
    print(f"  final: {fmt(cur)}  "
          f"(bound {base['bound_s']*1e3:.1f} -> {cur['bound_s']*1e3:.1f} "
          f"ms, {base['bound_s']/max(cur['bound_s'],1e-12):.2f}x)")
    return {"arch": arch, "shape": shape, "baseline": base, "final": cur,
            "final_config": incumbent, "log": log}


PAIR_A = ("qwen1.5-110b", "train_4k", {"fsdp": True}, [
    ("microbatch8",
     "activation live-set is ~86GB/chip with full-batch backward; 8 "
     "microbatches cut the live activations ~8x at unchanged math -> peak "
     "memory down, terms unchanged",
     {"microbatches": 8}),
    ("mea_bf16",
     "MEA attention einsums stream fp32 operands; bf16 operands halve "
     "attention HBM traffic (fp32 accum kept) -> memory term down by the "
     "attention share (~15-30% at S=4k)",
     {"cfg_overrides": {"mea_bf16": True}}),
    ("loss_chunk512",
     "the (B,S,152k) fp32 softmax is ~10GB live; chunking the loss over "
     "512-token slices bounds it ~8x -> peak down, bytes unchanged",
     {"cfg_overrides": {"loss_chunk": 512}}),
    ("remat_dots",
     "config remat=full recomputes every dot in the backward; "
     "dots-saveable trades ~1.3x memory for ~25% fewer recomputed FLOPs "
     "-> compute term down if memory still fits",
     {"remat": "dots"}),
    ("microbatch16",
     "if peak still >16GB after the above, halving microbatch size again "
     "buys the remaining fit",
     {"microbatches": 16}),
    ("grad_compress",
     "int8 DP gradient all-reduce with error feedback cuts the grad "
     "all-reduce payload ~2x vs bf16/4x vs fp32 -> collective term down",
     {"grad_compression": True}),
    ("int8_weights",
     "weight-only int8 on attn+mlp halves weight-read bytes (the "
     "decode/memory floor); NOTE the pre-fusion proxy double-counts the "
     "dequant converts, so the measured term may not drop even where "
     "real HBM traffic would",
     {"policy_rules": [["*mlp*", "int8"], ["*attn*", "int8"]]}),
    ("scale_out_2pods",
     "peak/chip is ~25GB at 256 chips: per-chip activations, grads and "
     "moments all halve at 512 chips (2x16x16) -> fits 16GB; per-chip "
     "terms halve too (this is the capacity answer, not a same-mesh "
     "speedup)",
     {"multi_pod": True}),
])

PAIR_B = ("deepseek-v2-236b", "train_4k", {"fsdp": True}, [
    # NOTE a "moe_fsdp_partial" variant (keep f-sharded expert weights and
    # psum the down-proj partials instead of gathering weights) was
    # REFUTED at the correctness stage: batch shards over the same
    # (pod,data) axes, so the psum mixes different data ranks' tokens.
    # Recorded here as a negative result; not measurable as a step.
    ("remat_dots_moe",
     "config remat=full re-runs the forward inside the backward, which "
     "REPEATS every MoE all-to-all and FSDP gather (~2x the collective "
     "term); saving dot outputs + the tagged a2a results "
     "(save_only_these_names('moe_recv')) removes the replay",
     {"remat": "dots+moe"}),
    ("capacity1.0",
     "MoE a2a payload scales with the capacity factor; cf 1.25->1.0 cuts "
     "a2a bytes 20% (dropped-token risk is a training-quality knob, "
     "measured separately by the O-task accuracy loop)",
     {"cfg_overrides": {"capacity_factor": 1.0}}),
    ("mea_bf16",
     "128-head MLA attention at S=4k streams large fp32 score tensors; "
     "bf16 operands halve that traffic",
     {"cfg_overrides": {"mea_bf16": True}}),
    ("microbatch4",
     "microbatching repeats the FSDP weight all-gather per microbatch "
     "(collective UP ~4x on the gather share) but divides activation "
     "peak ~4x; keep only if the fit wins the score",
     {"microbatches": 4}),
    ("loss_chunk512",
     "the (B,S,102k)-vocab fp32 softmax is multi-GB live; chunking "
     "bounds it",
     {"cfg_overrides": {"loss_chunk": 512}}),
    ("grad_compress",
     "int8 error-feedback compression on the DP grad all-reduce; "
     "deepseek grads are the largest absolute payload of any knob",
     {"grad_compression": True}),
])

PAIR_C = ("granite-moe-1b-a400m", "train_4k", {}, [
    ("pad_vocab",
     "vocab 49155 % 16 != 0 forces replicated embed/lm_head and "
     "replicated (B,S,49155) logits; padding to 49408 (x256) shards the "
     "vocab dim 16-way -> logits memory and lm_head flops per chip /16",
     {"cfg_overrides": {"pad_vocab_to_multiple": 256}}),
    ("zero1",
     "Adam moments are fp32 x 1.3B params replicated over data; ZeRO-1 "
     "shards them 16-way -> ~9.7GB/chip saved, no term change",
     {"zero1": True}),
    ("mea_bf16",
     "same bf16-operand attention traffic halving as pair A",
     {"cfg_overrides": {"mea_bf16": True}}),
    ("microbatch4",
     "granite activations at B_loc=16,S=4k dominate peak; 4 microbatches "
     "cut them 4x",
     {"microbatches": 4}),
    ("int8_experts",
     "QUANTIZATION O-task policy (int8 expert FFNs, alpha_q-validated on "
     "the DNN stage) executed at the lowered stage: int8 MXU dots double "
     "throughput -> compute term down ~2x on the expert share",
     {"policy_rules": [["*moe/experts*", "int8"], ["*mlp*", "int8"]]}),
    ("remat_dots_moe",
     "collective stayed dominant after the fit was won: remat replays "
     "the MoE a2a in the backward; saving the tagged a2a results "
     "removes the replayed collectives",
     {"remat": "dots+moe"}),
    ("capacity1.0",
     "a2a payload scales with capacity factor; 1.25 -> 1.0 trims 20%",
     {"cfg_overrides": {"capacity_factor": 1.0}}),
])

PAIRS = {"A": PAIR_A, "B": PAIR_B, "C": PAIR_C}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=["A", "B", "C"], default=None)
    args = ap.parse_args()
    keys = [args.pair] if args.pair else ["C", "A", "B"]  # cheapest first
    out = {}
    path = os.path.join(RESULTS_DIR, "perf_hillclimb.json")
    if os.path.exists(path):
        with open(path) as f:
            out = json.load(f)
    for k in keys:
        arch, shape, base_kw, steps = PAIRS[k]
        out[k] = run_pair(arch, shape, base_kw, steps)
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1, default=str)
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
