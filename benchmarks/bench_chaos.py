"""Chaos smoke suite: the self-healing serving row, standalone.

Runs only ``bench_serve._bench_chaos`` — the undersized paged engine
once fault-free and once under a fixed-seed FaultPlan (injected
allocation failure + poisoned decode segment), both with the boundary
invariant audit armed (``RecoveryPolicy(check_invariants=True)``), so
CI exercises the checker itself every run — so CI can gate the
recovery layer's contract without paying for the full serving suite.
Gates: every request finishes with tokens bit-identical to the
fault-free run, nothing dead-letters under the default retry policy,
the audit flags nothing, and the healing wall overhead stays within
``CHAOS_OVERHEAD_MAX``.  Results land in
``benchmarks/results/chaos_bench.json``.

The chaos row runs with telemetry enabled: it writes a Prometheus text
export and a JSONL request-lifecycle trace of the best faulted run to
``benchmarks/results/chaos_telemetry/`` (CI uploads both), and an extra
gate requires every injected fault fire to be attributable to a
specific request span (``telemetry.faults_attributed``).
"""

from __future__ import annotations

import time

import jax

try:
    from benchmarks.bench_serve import (CHAOS_OVERHEAD_MAX, LOAD_ARCH,
                                        _bench_chaos)
    from benchmarks.common import emit, save_json
except ImportError:
    from bench_serve import CHAOS_OVERHEAD_MAX, LOAD_ARCH, _bench_chaos
    from common import emit, save_json


def main():
    from repro.configs.registry import get_config
    from repro.models.api import build_model

    cfg = get_config(LOAD_ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    row = _bench_chaos(cfg, model, params)
    results = {"backend": jax.default_backend(), "t": time.time(),
               "chaos": row}
    # dead letters surface as structured (site, tenant, retries) records
    dl = ",".join(f"{d['site']}@{d['tenant']}x{d['retries']}"
                  for d in row["dead_letter_records"]) or "none"
    emit("serve_load_chaos", row["wall_chaos_s"] * 1e6,
         f"overhead={row['chaos_overhead']:.2f}x;"
         f"faults_fired={row['faults_fired']};"
         f"quarantines={row['recovery']['quarantines']};"
         f"dead_letters={dl};"
         f"tokens_equal={int(row['tokens_equal'])}")
    save_json("chaos_bench.json", results)
    if not (row["tokens_equal"] and row["all_finished"]
            and row["faults_fired"] >= 2):
        raise SystemExit(
            "chaos smoke failed: with an injected allocation failure and "
            "a poisoned decode segment, every request must still finish "
            "with tokens bit-identical to the fault-free run (see "
            "benchmarks/results/chaos_bench.json)")
    if row["dead_lettered"]:
        raise SystemExit("chaos smoke failed: the default retry policy "
                         "must absorb the fixed-seed plan without "
                         f"dead-lettering any request (records: {dl})")
    if row["invariant_violations"]:
        raise SystemExit("chaos smoke failed: the armed boundary "
                         "invariant audit flagged state corruption: "
                         f"{row['invariant_violations']}")
    if row["chaos_overhead"] > CHAOS_OVERHEAD_MAX:
        raise SystemExit(
            "chaos smoke failed: self-healing wall overhead "
            f"{row['chaos_overhead']:.2f}x exceeded "
            f"{CHAOS_OVERHEAD_MAX}x the fault-free run")
    tel = row["telemetry"]
    import os
    if not all(os.path.exists(p) for p in tel["exports"].values()):
        raise SystemExit("chaos smoke failed: telemetry exports missing "
                         f"from {tel['exports']}")
    if not tel["faults_attributed"]:
        raise SystemExit(
            "chaos smoke failed: an injected fault fire (or a "
            "quarantine/dead-letter it caused) could not be attributed "
            "to a specific request span in the JSONL trace (see "
            f"{tel['exports']['trace']})")
    return results


if __name__ == "__main__":
    main()
