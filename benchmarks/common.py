"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _block(out):
    import jax
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path
