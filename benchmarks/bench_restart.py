"""Crash-restart smoke suite: the durable-serving gate, standalone.

The only chaos gate that kills a real OS process.  A child interpreter
deploys a durable :class:`~repro.serving.plan.ServingPlan` (journal at
``benchmarks/results/restart_journal``), serves a preempting burst, and
dies mid-flight on a seeded ``process_crash`` (``os._exit(137)`` — no
atexit, no flushes beyond what the journal already fsync'd).  The
parent then does what an operator would: cold
:class:`~repro.serving.journal.RestartRecovery` from nothing but the
journal directory (plan JSON + WAL + spilled swap images), and gates

- the child actually died by injected crash (exit 137), leaving a
  parseable journal behind;
- recovery finishes EVERY journal-acknowledged request with tokens
  bit-identical to an uninterrupted oracle run, or as a typed dead
  letter (none expected under the default retry policy);
- the rebuilt engine's pool drains (free + pinned == allocatable) and
  no spilled swap image outlives recovery;
- a second replay of the post-recovery journal shows every request
  terminal — the journal converges, it doesn't grow open ends;
- a torn-tail variant (bytes chopped off the last segment of a copy of
  the crashed journal) degrades to restart-from-checkpoint and still
  recovers bit-identically — tail damage is a legal crash state, never
  a replay failure.

The post-crash journal directory is preserved verbatim for the CI
artifact; recovery runs against copies.  Results land in
``benchmarks/results/restart_bench.json``.
"""

from __future__ import annotations

import os
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np

try:
    from benchmarks.bench_serve import LOAD_ARCH
    from benchmarks.common import RESULTS_DIR, emit, save_json
except ImportError:
    from bench_serve import LOAD_ARCH
    from common import RESULTS_DIR, emit, save_json

JOURNAL_DIR = os.path.join(RESULTS_DIR, "restart_journal")
CRASH_BOUNDARY = 5      # mid-burst: admissions done, preemptions live
N_REQUESTS = 4
PROMPT_LEN = 12
GEN = 24


def _plan(journal_dir: str):
    """A deliberately undersized pool (2 slots, 8 pages for 4 requests'
    lifetimes) so the crash lands with preempted requests' swap images
    spilled beside the journal — the hardest recovery lane."""
    from repro.serving import (DurabilityPolicy, PagedCacheConfig,
                               ServingPlan)
    return ServingPlan(
        arch=LOAD_ARCH,
        cache=PagedCacheConfig(page_size=8, n_pages=8, max_slots=2,
                               max_blocks=5, segment_len=4),
        max_prompt_len=PROMPT_LEN, max_new_tokens=GEN,
        durability=DurabilityPolicy(enabled=True,
                                    journal_dir=journal_dir))


def _model():
    import jax
    from repro.configs.registry import get_config
    from repro.models.api import build_model
    cfg = get_config(LOAD_ARCH, smoke=True)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _requests(cfg):
    rng = np.random.default_rng(0)
    from repro.serving import Request
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=PROMPT_LEN).astype(np.int32),
                    max_new_tokens=GEN)
            for i in range(N_REQUESTS)]


def _child(journal_dir: str) -> None:
    """The process that dies: serve the burst under a seeded crash."""
    from repro.serving import (FaultPlan, PagedServingEngine,
                               ProcessCrashed)
    cfg, model, params = _model()
    engine = PagedServingEngine.from_plan(model, _plan(journal_dir))
    try:
        engine.run(_requests(cfg), params,
                   faults=FaultPlan.at(process_crash=CRASH_BOUNDARY))
    except ProcessCrashed:
        os._exit(137)                   # kill -9 semantics: no cleanup
    os._exit(3)                         # crash never fired: gate failure


def _recover(journal_dir: str, model, params, *, engine=None) -> dict:
    from repro.serving import RestartRecovery
    t0 = time.perf_counter()
    rr = RestartRecovery(journal_dir)
    out = rr.resume(model, params, engine=engine)
    out["wall_s"] = time.perf_counter() - t0
    out["acked"] = sorted(rr.replay.requests, key=str)
    return out


def _gate_recovery(tag: str, out: dict, oracle: dict,
                   journal_dir: str, allocatable: int) -> dict:
    """The bit-identical-or-typed-dead-letter contract + leak audit."""
    from repro.serving import RequestFailed, replay_journal
    got = {r.rid: r for r in out["requests"]}
    if sorted(got, key=str) != out["acked"]:
        raise SystemExit(
            f"restart smoke [{tag}]: recovery returned rids "
            f"{sorted(got, key=str)} != journal-acknowledged "
            f"{out['acked']}")
    dead, mismatched = [], []
    for rid, r in got.items():
        if r.failure is not None:
            if not isinstance(r.failure, RequestFailed):
                raise SystemExit(
                    f"restart smoke [{tag}]: rid {rid} failed without "
                    f"a typed record: {r.failure!r}")
            dead.append(rid)
        elif r.tokens != oracle[rid]:
            mismatched.append(rid)
    if mismatched:
        raise SystemExit(
            f"restart smoke [{tag}]: rids {mismatched} finished with "
            "tokens diverging from the uninterrupted oracle run — "
            "crash-restart recovery must be bit-identical (see "
            "benchmarks/results/restart_bench.json)")
    if dead:
        raise SystemExit(
            f"restart smoke [{tag}]: rids {dead} dead-lettered; the "
            "default retry policy must absorb one process crash")
    s = out["stats"]
    if s["free_pages"] + s["pinned_pages"] != allocatable:
        raise SystemExit(
            f"restart smoke [{tag}]: leaked pages after recovery — "
            f"free={s['free_pages']} pinned={s['pinned_pages']} "
            f"allocatable={allocatable}")
    orphans = [f for f in os.listdir(journal_dir)
               if f.startswith("img-")]
    if orphans:
        raise SystemExit(
            f"restart smoke [{tag}]: spilled swap images outlived "
            f"recovery: {orphans}")
    rp = replay_journal(journal_dir)
    open_ends = [str(rid) for rid, r in rp.requests.items()
                 if r.status not in ("completed", "dead")]
    if open_ends:
        raise SystemExit(
            f"restart smoke [{tag}]: post-recovery journal replay "
            f"leaves rids {open_ends} non-terminal")
    return {"acked": [str(a) for a in out["acked"]],
            "recovered": out["recovered"], "wall_s": out["wall_s"],
            "journal": s.get("journal", {})}


def main():
    import jax
    from repro.serving import PagedServingEngine, replay_journal

    # ---- oracle: the uninterrupted run (durability off) -------------
    cfg, model, params = _model()
    plan = _plan(JOURNAL_DIR)
    import dataclasses
    from repro.serving import DurabilityPolicy
    engine = PagedServingEngine.from_plan(
        model, dataclasses.replace(plan, durability=DurabilityPolicy()))
    oracle_reqs = _requests(cfg)
    oracle_stats = engine.run(oracle_reqs, params)
    oracle = {r.rid: list(r.tokens) for r in oracle_reqs}
    if oracle_stats["preemptions"] < 1:
        raise SystemExit("restart smoke: the burst must preempt so the "
                         "crash leaves spilled swap images to recover")

    # ---- the crash: a child interpreter dies mid-burst --------------
    if os.path.isdir(JOURNAL_DIR):
        shutil.rmtree(JOURNAL_DIR)
    src_root = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child",
         JOURNAL_DIR], env=env, capture_output=True, text=True)
    child_wall = time.perf_counter() - t0
    if proc.returncode != 137:
        raise SystemExit(
            f"restart smoke: child exited {proc.returncode}, expected "
            f"137 (injected process_crash at boundary {CRASH_BOUNDARY})"
            f"\n--- child stderr ---\n{proc.stderr[-2000:]}")
    crashed = replay_journal(JOURNAL_DIR)
    if not crashed.requests:
        raise SystemExit("restart smoke: the crashed child left an "
                         "empty journal — nothing was acknowledged")
    if crashed.plan is None:
        raise SystemExit("restart smoke: no serving_plan.json beside "
                         "the crashed journal")

    # ---- recovery gates run on copies; JOURNAL_DIR stays the -------
    # ---- pristine post-crash state for the CI artifact -------------
    allocatable = plan.cache.allocatable_pages
    rows = {}
    with tempfile.TemporaryDirectory() as tmp:
        # cold restart: nothing but the journal directory (plan JSON
        # decides the engine — the operator path)
        cold = os.path.join(tmp, "cold")
        shutil.copytree(JOURNAL_DIR, cold)
        rows["cold"] = _gate_recovery(
            "cold", _recover(cold, model, params), oracle, cold,
            allocatable)
        # torn tail: chop bytes off the last WAL segment of another
        # copy — must degrade to restart-from-checkpoint, not fail
        torn = os.path.join(tmp, "torn")
        shutil.copytree(JOURNAL_DIR, torn)
        segs = sorted(f for f in os.listdir(torn)
                      if f.startswith("wal-"))
        last = os.path.join(torn, segs[-1])
        with open(last, "r+b") as f:
            f.truncate(max(0, os.path.getsize(last) - 17))
        rows["torn"] = _gate_recovery(
            "torn", _recover(torn, model, params, engine=engine),
            oracle, torn, allocatable)

    results = {"backend": jax.default_backend(), "t": time.time(),
               "crash_boundary": CRASH_BOUNDARY,
               "child_exit": proc.returncode,
               "child_wall_s": child_wall,
               "oracle_preemptions": int(oracle_stats["preemptions"]),
               "crashed_journal": {
                   "n_records": crashed.n_records,
                   "truncated": crashed.truncated,
                   "by_status": {
                       str(rid): r.status
                       for rid, r in sorted(crashed.requests.items(),
                                            key=lambda kv: str(kv[0]))},
               },
               "cold": rows["cold"], "torn": rows["torn"]}
    save_json("restart_bench.json", results)
    rec = rows["cold"]["recovered"]
    emit("serve_restart", rows["cold"]["wall_s"] * 1e6,
         f"child_exit=137;boundary={CRASH_BOUNDARY};"
         f"acked={len(rows['cold']['acked'])};"
         f"replayed_completed={rec['replayed_completed']};"
         f"image_restores={rec['image_restores']};"
         f"restarts={rec['restarts']};requeued={rec['requeued']};"
         f"torn_tail_ok=1;bit_identical=1")
    return results


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--child":
        _child(sys.argv[2])
    else:
        main()
