"""§Perf addendum measurements (run after perf_hillclimb):

A1. qwen110 mb16 + int8 weights, POST STE FIX — the pre-fix run recorded
    a bogus win (zero-grad backward); this is the honest number.
A2. qwen110 fit-combo: microbatch32 + loss_chunk256 (greedy search missed
    the combination; hypothesis: remaining 9GB of peak is logits+acts).
C1. granite final config + remat_dots_moe + capacity1.0 (collective
    attack after the fit was won).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import json

from benchmarks.perf_hillclimb import fmt, measure
from benchmarks.common import RESULTS_DIR

RUNS = {
    "A1_int8_ste": ("qwen1.5-110b", "train_4k",
                    {"fsdp": True, "microbatches": 16,
                     "policy_rules": [["*mlp*", "int8"],
                                      ["*attn*", "int8"]]}),
    "A2_fit_combo": ("qwen1.5-110b", "train_4k",
                     {"fsdp": True, "microbatches": 32,
                      "cfg_overrides": {"loss_chunk": 256}}),
    "C1_collective": ("granite-moe-1b-a400m", "train_4k",
                      {"zero1": True, "microbatches": 4,
                       "remat": "dots+moe",
                       "cfg_overrides": {"pad_vocab_to_multiple": 256,
                                         "capacity_factor": 1.0}}),
}


def main():
    path = os.path.join(RESULTS_DIR, "perf_addendum.json")
    out = {}
    if os.path.exists(path):
        with open(path) as f:
            out = json.load(f)
    for name, (arch, shape, kw) in RUNS.items():
        print(f"== {name}: {arch} x {shape} {kw}", flush=True)
        try:
            r = measure(arch, shape, kw)
            print("  " + fmt(r), flush=True)
            out[name] = {"arch": arch, "shape": shape, "config": kw,
                         "roofline": r}
        except Exception as e:  # noqa: BLE001
            print(f"  ERROR {e}")
            out[name] = {"arch": arch, "shape": shape, "config": kw,
                         "error": repr(e)}
        with open(path, "w") as f:
            json.dump(out, f, indent=1, default=str)
    print("wrote", path)


if __name__ == "__main__":
    main()
