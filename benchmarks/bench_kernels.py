"""Kernel micro-benchmarks (CPU interpret mode measures dispatch/semantics;
the derived column reports the structural compute saving, which is what
transfers to TPU).

Every kernel row now carries a tuned-vs-default comparison: the autotuner
(kernels/autotune.py) searches the pruned tile space for the benchmarked
shape and the ``*_tuned`` row reports the winning config next to the fixed
128x128 default.  The tuned config is never slower than the default: the
default is part of the candidate space, and if a re-measurement regresses
(timing noise) the default config is kept.  Cache hits skip the search
entirely — re-running this benchmark with a warm REPRO_AUTOTUNE_CACHE only
re-times the winner.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune
from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                               compact_block_index)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_matmul import quant_matmul
from repro.sparsity.masks import block_map, block_mask

try:
    from benchmarks.common import emit, save_json, timeit
except ImportError:
    from common import emit, save_json, timeit

TUNE_OPTS = dict(max_trials=6, iters=2, warmup=1)


def _cfg_str(cfg: dict) -> str:
    return "/".join(f"{k.split('_')[-1]}{v}" for k, v in sorted(cfg.items()))


def tuned_vs_default(kernel: str, problem: dict, call, default_us: float,
                     results: dict) -> None:
    """Emit the ``<kernel>_tuned`` row: tune for ``problem``, re-time the
    winner via ``call(config)``, and keep the default on a noise regression
    (the tuned column is never slower than the default column)."""
    res = autotune.tune(kernel, problem, **TUNE_OPTS)
    default_cfg = autotune.KERNELS[kernel].default_config
    cfg = res.config
    if cfg == default_cfg:
        tuned_us = default_us
    else:
        tuned_us = timeit(lambda: call(cfg), iters=3)
        if tuned_us > default_us:
            cfg, tuned_us = default_cfg, default_us
    speedup = default_us / max(tuned_us, 1e-9)
    emit(f"kernel_{kernel}_tuned", tuned_us,
         f"default_us={default_us:.1f};config={_cfg_str(cfg)};"
         f"speedup={speedup:.2f}x;cached={int(res.cached)}")
    results[f"{kernel}_tuned_us"] = tuned_us
    results[f"{kernel}_default_us"] = default_us
    results[f"{kernel}_tuned_config"] = cfg
    results[f"{kernel}_tune_cached"] = res.cached


def main():
    results = {}
    key = jax.random.PRNGKey(0)
    m, k, n = 256, 512, 512
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))

    # quant matmul: int8 weight bytes vs fp32
    us = timeit(lambda: quant_matmul(x, w, interpret=True), iters=3)
    emit("kernel_quant_matmul", us, "weight_bytes_reduction=4x")
    results["quant_matmul_us"] = us
    tuned_vs_default(
        "quant_matmul",
        autotune.quant_matmul_problem(x.shape, w.shape, x.dtype),
        lambda cfg: quant_matmul(x, w, interpret=True,
                                 block_m=cfg["block_m"],
                                 block_n=cfg["block_n"],
                                 block_k=cfg["block_k"]),
        us, results)

    # flash attention: causal tile skipping
    b, s, h, d = 1, 256, 2, 64
    q = jax.random.normal(key, (b, s, h, d))
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
    us = timeit(lambda: flash_attention(q, kk, v, causal=True,
                                        interpret=True), iters=3)
    emit("kernel_flash_attention", us, "causal_tile_skipping=~2x_flops")
    results["flash_attention_us"] = us
    tuned_vs_default(
        "flash_attention",
        autotune.flash_attention_problem(q.shape, kk.shape, q.dtype),
        lambda cfg: flash_attention(q, kk, v, causal=True, interpret=True,
                                    block_q=cfg["block_q"],
                                    block_kv=cfg["block_kv"]),
        us, results)

    # block-sparse: trip count scales with live blocks
    for rate in (0.0, 0.5, 0.75):
        mask = block_mask(w, rate=rate, block=128)
        kidx = jnp.asarray(compact_block_index(
            block_map(np.asarray(mask), 128)))
        wm = w * mask
        us = timeit(lambda: block_sparse_matmul(x, wm, kidx,
                                                interpret=True), iters=3)
        trips = int(kidx.shape[1])
        emit(f"kernel_bsmm_rate{rate}", us,
             f"k_trips={trips}/{k//128};structural_saving="
             f"{1 - trips/(k//128):.2f}")
        results[f"bsmm_rate{rate}_trips"] = trips
        if rate == 0.5:
            tuned_vs_default(
                "block_sparse_matmul",
                autotune.block_sparse_matmul_problem(
                    x.shape, w.shape, x.dtype, max_live=trips),
                lambda cfg: block_sparse_matmul(x, wm, kidx, interpret=True,
                                                block_m=cfg["block_m"]),
                us, results)
    save_json("kernel_bench.json", results)
    return results


if __name__ == "__main__":
    main()
