"""Kernel micro-benchmarks (CPU interpret mode measures dispatch/semantics;
the derived column reports the structural compute saving, which is what
transfers to TPU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                               compact_block_index)
from repro.kernels.quant_matmul import quant_matmul
from repro.sparsity.masks import block_map, block_mask

try:
    from benchmarks.common import emit, save_json, timeit
except ImportError:
    from common import emit, save_json, timeit


def main():
    results = {}
    key = jax.random.PRNGKey(0)
    m, k, n = 256, 512, 512
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))

    # quant matmul: int8 weight bytes vs fp32
    us = timeit(lambda: quant_matmul(x, w, interpret=True), iters=3)
    emit("kernel_quant_matmul", us, "weight_bytes_reduction=4x")
    results["quant_matmul_us"] = us

    # block-sparse: trip count scales with live blocks
    for rate in (0.0, 0.5, 0.75):
        mask = block_mask(w, rate=rate, block=128)
        kidx = jnp.asarray(compact_block_index(
            block_map(np.asarray(mask), 128)))
        wm = w * mask
        us = timeit(lambda: block_sparse_matmul(x, wm, kidx,
                                                interpret=True), iters=3)
        trips = int(kidx.shape[1])
        emit(f"kernel_bsmm_rate{rate}", us,
             f"k_trips={trips}/{k//128};structural_saving="
             f"{1 - trips/(k//128):.2f}")
        results[f"bsmm_rate{rate}_trips"] = trips
    save_json("kernel_bench.json", results)
    return results


if __name__ == "__main__":
    main()
