"""Serving-path benchmark: prefill vs decode tokens/s across decode
execution variants.

Three variants per smoke shape, all generating identical greedy tokens:

- ``loop_jnp``    — the seed path: per-token Python loop, jnp decode
                    attention (one host round-trip + dispatch per token);
- ``scan_jnp``    — the fused path: all decode steps in one
                    ``jax.lax.scan`` dispatch, jnp decode attention;
- ``scan_kernel`` — fused scan + the flash_decode Pallas kernel
                    (interpret mode on CPU; Mosaic on TPU).

Compile/warmup runs before any timed region and prefill is timed apart
from decode (launch/serve.py::timed_generate), so the rows are pure
serving-trajectory numbers.  The shape grid covers the two decode cache
layouts: linear (qwen2 GQA) and sliding-window ring buffer (danube).

Rows land in ``benchmarks/results/serve_bench.json`` with a
``not_slower_than_seed`` verdict per shape: the scan'd flash-decode path
must never lose to the seed Python-loop jnp path.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

try:
    from benchmarks.common import emit, save_json
except ImportError:
    from common import emit, save_json

# (arch, batch, prompt_len, gen): one linear-cache GQA arch, one
# sliding-window ring-buffer arch — the two decode masking regimes.
SERVE_SHAPES = [
    ("qwen2-7b", 2, 32, 16),
    ("h2o-danube-3-4b", 2, 32, 16),
]

VARIANTS = {                      # name -> (scan, kernels)
    "loop_jnp": (False, False),
    "scan_jnp": (True, False),
    "scan_kernel": (True, True),
}
ITERS = 3


def _bench_shape(arch: str, batch: int, prompt_len: int, gen: int) -> dict:
    from repro.configs.registry import get_config
    from repro.data.synthetic import lm_tokens
    from repro.launch.serve import generate, make_serve_fns, timed_generate
    from repro.models.api import build_model

    cfg = get_config(arch, smoke=True)
    prompts = jnp.asarray(lm_tokens(batch * prompt_len, cfg.vocab_size,
                                    seed=1).reshape(batch, prompt_len))
    cache_len = prompt_len + gen + 1
    interpret = jax.default_backend() != "tpu"
    row: dict = {"arch": cfg.name, "batch": batch,
                 "prompt_len": prompt_len, "gen": gen}

    # loop/scan is a call-time choice, so the two jnp variants share one
    # model + jitted fns; params are model-independent given the config
    models = {False: build_model(cfg),
              True: build_model(cfg, use_kernels=True,
                                interpret=interpret)}
    params = models[False].init(jax.random.PRNGKey(0))
    fns = {k: make_serve_fns(m) for k, m in models.items()}

    tokens = {}
    for name, (scan, kernels) in VARIANTS.items():
        model = models[kernels]
        out = generate(model, params, prompts, gen, cache_len,
                       scan=scan, fns=fns[kernels])  # compile (untimed)
        tokens[name] = [list(map(int, r)) for r in out.tolist()]
        best = None
        for _ in range(ITERS):
            _, t = timed_generate(model, params, prompts, gen, cache_len,
                                  scan=scan, fns=fns[kernels])
            best = t if best is None else {
                k: min(best[k], t[k]) for k in t}
        row[name] = {
            "prefill_s": best["prefill_s"],
            "decode_s": best["decode_s"],
            "prefill_tokens_per_s":
                batch * prompt_len / max(best["prefill_s"], 1e-9),
            "decode_tokens_per_s":
                batch * (gen - 1) / max(best["decode_s"], 1e-9),
        }

    # all variants must decode the same greedy tokens — the full (B, gen)
    # grid, not a truncated sample
    row["samples_agree"] = len({tuple(map(tuple, t))
                                for t in tokens.values()}) == 1
    row["sample"] = tokens["scan_kernel"][0][:8]
    base = row["loop_jnp"]["decode_tokens_per_s"]
    for name in ("scan_jnp", "scan_kernel"):
        row[name]["speedup_vs_loop_jnp"] = \
            row[name]["decode_tokens_per_s"] / max(base, 1e-9)
    row["not_slower_than_seed"] = \
        row["scan_kernel"]["decode_tokens_per_s"] >= base
    return row


def main():
    results = {"backend": jax.default_backend(), "t": time.time(),
               "shapes": []}
    for arch, batch, prompt_len, gen in SERVE_SHAPES:
        row = _bench_shape(arch, batch, prompt_len, gen)
        results["shapes"].append(row)
        tag = f"serve_{row['arch']}"
        emit(f"{tag}_prefill", row["loop_jnp"]["prefill_s"] * 1e6,
             f"prefill_tok_s="
             f"{row['loop_jnp']['prefill_tokens_per_s']:.1f}")
        for name in VARIANTS:
            v = row[name]
            derived = f"decode_tok_s={v['decode_tokens_per_s']:.1f}"
            if name != "loop_jnp":
                derived += (f";vs_loop_jnp="
                            f"{v['speedup_vs_loop_jnp']:.2f}x")
            emit(f"{tag}_decode_{name}", v["decode_s"] * 1e6, derived)
        emit(f"{tag}_verdict", 0.0,
             f"not_slower_than_seed={int(row['not_slower_than_seed'])};"
             f"samples_agree={int(row['samples_agree'])}")
    save_json("serve_bench.json", results)
    # the speed verdict gates CI, it is not just an artifact field.
    # samples_agree is reported but not gated: greedy argmax can
    # legitimately flip on float-reduction-order ties between the kernel
    # and the oracle — numerical equivalence is pinned (with tolerances)
    # by tests/test_decode_kernel.py, the right tool for that claim.
    slow = [r["arch"] for r in results["shapes"]
            if not r["not_slower_than_seed"]]
    if slow:
        raise SystemExit(f"serve bench regression on {slow}: the scan'd "
                         f"flash-decode path must never be slower than "
                         f"the seed Python-loop jnp path")
    return results


if __name__ == "__main__":
    main()
