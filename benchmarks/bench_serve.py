"""Serving-path benchmark: prefill vs decode tokens/s across decode
execution variants.

Three variants per smoke shape, all generating identical greedy tokens:

- ``loop_jnp``    — the seed path: per-token Python loop, jnp decode
                    attention (one host round-trip + dispatch per token);
- ``scan_jnp``    — the fused path: all decode steps in one
                    ``jax.lax.scan`` dispatch, jnp decode attention;
- ``scan_kernel`` — fused scan + the flash_decode Pallas kernel
                    (interpret mode on CPU; Mosaic on TPU).

Compile/warmup runs before any timed region and prefill is timed apart
from decode (launch/serve.py::timed_generate), so the rows are pure
serving-trajectory numbers.  The shape grid covers the two decode cache
layouts: linear (qwen2 GQA) and sliding-window ring buffer (danube).

Rows land in ``benchmarks/results/serve_bench.json`` with a
``not_slower_than_seed`` verdict per shape: the scan'd flash-decode path
must never lose to the seed Python-loop jnp path.

A second, load-driven suite (``_bench_load``) drives the paged
continuous-batching engine (src/repro/serving/) against the single-stream
scan path under request traffic: one burst row (8 requests arriving at
once — the concurrency acceptance row) and Poisson-arrival rows at rates
below and above the single-stream service capacity.  Each row reports
aggregate decode tokens/s and p50/p95 per-request latency
(completion − arrival).  Two gates: the paged burst row must reach >= 2x
the single-stream aggregate *decode* tokens/s (prefill is excluded from
the ratio — admissions are gated separately by the shared-prefix row
below; wall-clock speedup is reported alongside), and the engine's
greedy tokens must be identical, request by request, to the contiguous
jnp-oracle scan path (kernel-vs-oracle equivalence inside the engine is
pinned separately by tests/test_paged.py).

A third row (``_bench_prefix``) measures the admission path itself: 8
requests sharing a common system-prompt prefix, batched-ragged
prefill + prefix sharing (the default engine) vs the PR-3 serial batch-1
admission path.  It reports prefix hit-rate, pages saved vs an unshared
pool, and summed admission-prefill latency; the batched path must admit
the burst >= 1.5x faster than the serial path (gated), with
request-by-request token equality between the two engines (gated).

Two resource-manager rows exercise the quota-aware preemptive scheduler
(serving/resources.py):

- ``tenants2`` — two tenants on one pool, each budgeted half of it: a
  latency-sensitive tenant (weight 2) receives spaced requests while a
  batch tenant dumps an 8-request burst at t=0.  Gated: the protected
  tenant's p95 latency stays within 1.5x its solo run on the same
  engine (budgets make svc's pages unreachable by the burst, so the
  only interference left is shared segment dispatches), and the svc
  tenant is never preempted.  Per-tenant admitted/preempted/restored/
  pages_swapped counters from ``ResourceManager.stats()`` land in the
  row.
- ``oversubscribed`` — total lifetime page demand exceeds the pool, so
  growth-on-demand must run at least one host-swap preempt/restore
  cycle.  Gated: every request completes, >= 1 preemption actually
  happened, and per-request tokens are bit-identical to an
  unconstrained big-pool run.

A chaos row (``_bench_chaos``, also runnable alone via
``benchmarks/bench_chaos.py`` — the CI chaos smoke) replays the
undersized geometry under a fixed-seed FaultPlan (an injected
allocation failure + a poisoned decode segment) and gates the recovery
layer's contract: every request finishes token-identical to the
fault-free run within a bounded wall-overhead multiple.

Engine rows run with telemetry enabled (serving/observe.py): latency
percentiles come from the engine's own per-request records
(``result()["requests"]``), each row embeds a ``render_summary()``
metrics snapshot, and the chaos row additionally exports a Prometheus
text file + JSONL lifecycle trace of its best faulted run to
``benchmarks/results/chaos_telemetry/`` with every fault fire gated
attributable to a request span.  The cost of enabling telemetry is
itself gated by ``benchmarks/bench_obs.py``.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, save_json
except ImportError:
    from common import emit, save_json

# (arch, batch, prompt_len, gen): one linear-cache GQA arch, one
# sliding-window ring-buffer arch — the two decode masking regimes.
SERVE_SHAPES = [
    ("qwen2-7b", 2, 32, 16),
    ("h2o-danube-3-4b", 2, 32, 16),
]

VARIANTS = {                      # name -> (scan, kernels)
    "loop_jnp": (False, False),
    "scan_jnp": (True, False),
    "scan_kernel": (True, True),
}
ITERS = 3


def _bench_shape(arch: str, batch: int, prompt_len: int, gen: int) -> dict:
    from repro.configs.registry import get_config
    from repro.data.synthetic import lm_tokens
    from repro.launch.serve import generate, make_serve_fns, timed_generate
    from repro.models.api import build_model

    cfg = get_config(arch, smoke=True)
    prompts = jnp.asarray(lm_tokens(batch * prompt_len, cfg.vocab_size,
                                    seed=1).reshape(batch, prompt_len))
    cache_len = prompt_len + gen + 1
    interpret = jax.default_backend() != "tpu"
    row: dict = {"arch": cfg.name, "batch": batch,
                 "prompt_len": prompt_len, "gen": gen}

    # loop/scan is a call-time choice, so the two jnp variants share one
    # model + jitted fns; params are model-independent given the config
    models = {False: build_model(cfg),
              True: build_model(cfg, use_kernels=True,
                                interpret=interpret)}
    params = models[False].init(jax.random.PRNGKey(0))
    fns = {k: make_serve_fns(m) for k, m in models.items()}

    tokens = {}
    for name, (scan, kernels) in VARIANTS.items():
        model = models[kernels]
        out = generate(model, params, prompts, gen, cache_len,
                       scan=scan, fns=fns[kernels])  # compile (untimed)
        tokens[name] = [list(map(int, r)) for r in out.tolist()]
        best = None
        for _ in range(ITERS):
            _, t = timed_generate(model, params, prompts, gen, cache_len,
                                  scan=scan, fns=fns[kernels])
            best = t if best is None else {
                k: min(best[k], t[k]) for k in t}
        row[name] = {
            "prefill_s": best["prefill_s"],
            "decode_s": best["decode_s"],
            "prefill_tokens_per_s":
                batch * prompt_len / max(best["prefill_s"], 1e-9),
            "decode_tokens_per_s":
                batch * (gen - 1) / max(best["decode_s"], 1e-9),
        }

    # all variants must decode the same greedy tokens — the full (B, gen)
    # grid, not a truncated sample
    row["samples_agree"] = len({tuple(map(tuple, t))
                                for t in tokens.values()}) == 1
    row["sample"] = tokens["scan_kernel"][0][:8]
    base = row["loop_jnp"]["decode_tokens_per_s"]
    for name in ("scan_jnp", "scan_kernel"):
        row[name]["speedup_vs_loop_jnp"] = \
            row[name]["decode_tokens_per_s"] / max(base, 1e-9)
    row["not_slower_than_seed"] = \
        row["scan_kernel"]["decode_tokens_per_s"] >= base
    return row


# ---------------------------------------------------------- load suite
LOAD_ARCH = "qwen2-7b"          # linear cache: the paged-eligible shape
LOAD_PROMPT, LOAD_GEN = 32, 16
LOAD_SLOTS = 8                  # in-flight batch width = the 8-concurrent row
LOAD_BURST = 8                  # requests in the burst (acceptance) row
LOAD_POISSON_N = 10             # requests per Poisson row
POISSON_SEED = 7                # default Poisson-row profile seed (--seed)


def _load_requests(cfg, n, seed):
    # one TrafficProfile expansion — the same entry point the SERVE
    # task's replay scorer uses, so bench rows and searched plans are
    # measured on identical request streams
    from repro.serving.traffic import TrafficProfile
    return TrafficProfile(name=f"load{n}", n_requests=n,
                          prompt_len=LOAD_PROMPT,
                          max_new_tokens=LOAD_GEN,
                          seed=seed).requests(cfg.vocab_size)


def _poisson_profile(tag, rate, seed):
    from repro.serving.traffic import TrafficProfile
    return TrafficProfile(name=f"poisson_{tag}",
                          n_requests=LOAD_POISSON_N, arrival_rate=rate,
                          prompt_len=LOAD_PROMPT,
                          max_new_tokens=LOAD_GEN, seed=seed)


def _single_stream(model, fns, params, reqs):
    """FIFO baseline: one request at a time through the fused contiguous
    scan path (scan_jnp — the best pre-paging serving configuration)."""
    from repro.launch.serve import timed_generate
    cache_len = LOAD_PROMPT + LOAD_GEN + 1
    lat, tokens = [], {}
    decode_s = 0.0
    t0 = time.perf_counter()
    for req in sorted(reqs, key=lambda r: r.arrival):
        now = time.perf_counter() - t0
        if req.arrival > now:
            time.sleep(req.arrival - now)
        out, t = timed_generate(model, params,
                                jnp.asarray(req.prompt[None]), LOAD_GEN,
                                cache_len, scan=True, fns=fns)
        decode_s += t["decode_s"]
        tokens[req.rid] = [int(tk) for tk in np.asarray(out)[0]]
        lat.append((time.perf_counter() - t0) - req.arrival)
    wall = time.perf_counter() - t0
    return {"wall_s": wall, "decode_s": decode_s,
            "tokens_per_s": len(reqs) * LOAD_GEN / max(wall, 1e-9),
            "decode_tokens_per_s":
                len(reqs) * (LOAD_GEN - 1) / max(decode_s, 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95))}, tokens


def _fresh_obs():
    """One enabled telemetry store per measured engine run: rows embed a
    render_summary() snapshot (TTFT/queue-wait percentiles, preemption
    and dead-letter counters) scoped to that run alone."""
    from repro.serving import Observability, ObservabilityPolicy
    return Observability.from_policy(ObservabilityPolicy(enabled=True))


def _paged(engine, params, reqs):
    stats = engine.run(reqs, params, obs=_fresh_obs())
    # per-request latency comes from the engine's own telemetry records
    # (result()["requests"]), not recomputed from Request fields
    lat = [rec["e2e_s"] for rec in stats["requests"]
           if rec["e2e_s"] is not None]
    wall = stats["wall_s"]
    return {"wall_s": wall, "decode_s": stats["decode_s"],
            "tokens_per_s": len(reqs) * LOAD_GEN / max(wall, 1e-9),
            "decode_tokens_per_s":
                len(reqs) * (LOAD_GEN - 1) / max(stats["decode_s"], 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "n_segments": stats["n_segments"],
            "metrics": stats["metrics"]}, \
        {r.rid: list(r.tokens) for r in reqs}


def _bench_load(profile=None, seed: int = POISSON_SEED) -> dict:
    import dataclasses

    from repro.configs.registry import get_config
    from repro.launch.serve import generate, make_serve_fns
    from repro.models.api import build_model
    from repro.serving import PagedCacheConfig, PagedServingEngine
    from repro.serving.engine import warmup
    from repro.serving.paged_cache import (preferred_page_size,
                                           preferred_segment_len)

    cfg = get_config(LOAD_ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fns = make_serve_fns(model)
    cap_tokens = LOAD_PROMPT + LOAD_GEN + 1
    # both serving-schedule knobs read back from the autotuner: the pool
    # granule (flash_decode_paged) and the boundary cadence
    # (paged_segment, whence the growth granule)
    page_size = preferred_page_size(cfg, LOAD_SLOTS, cap_tokens)
    segment_len = preferred_segment_len(cfg, LOAD_SLOTS, cap_tokens)
    blocks = -(-cap_tokens // page_size)
    pcfg = PagedCacheConfig(page_size=page_size,
                            n_pages=LOAD_SLOTS * blocks + 1,
                            max_slots=LOAD_SLOTS, max_blocks=blocks,
                            segment_len=segment_len)
    engine = PagedServingEngine(model, pcfg)

    # compile both paths outside every timed region
    generate(model, params,
             jnp.asarray(_load_requests(cfg, 1, 99)[0].prompt[None]),
             LOAD_GEN, cap_tokens, scan=True, fns=fns)
    warmup(engine, params, LOAD_PROMPT, LOAD_GEN)
    # the batched admission path compiles one dispatch per (row-bucket,
    # suffix-bucket) pair; Poisson arrivals hit boundaries of 1..8
    # admissions, so visit every power-of-two row bucket up front
    for k in (2, 3, LOAD_BURST):
        engine.run(_load_requests(cfg, k, seed=97), params)

    suite = {"arch": cfg.name, "prompt_len": LOAD_PROMPT, "gen": LOAD_GEN,
             "slots": LOAD_SLOTS, "page_size": page_size,
             "segment_len": segment_len, "rows": []}

    # burst row: 8 concurrent requests — the acceptance measurement
    # (best-of-ITERS per path, selected on the gated decode time:
    # single-run timings are noisy on CI)
    base_row = base_tok = paged_row = paged_tok = None
    for _ in range(ITERS):
        b_row, b_tok = _single_stream(
            model, fns, params, _load_requests(cfg, LOAD_BURST, 1))
        if base_row is None or b_row["decode_s"] < base_row["decode_s"]:
            base_row, base_tok = b_row, b_tok
        p_row, p_tok = _paged(
            engine, params, _load_requests(cfg, LOAD_BURST, 1))
        if paged_row is None or p_row["decode_s"] < paged_row["decode_s"]:
            paged_row, paged_tok = p_row, p_tok
    # the gated ratio is *aggregate decode* tokens/s: admission prefill
    # cost differs by design now (the engine batches admissions into one
    # ragged dispatch) and is gated on its own row (_bench_prefix), so it
    # is excluded here to keep this the pure continuous-batching decode
    # quantity; end-to-end wall speedup is reported alongside
    speedup = (paged_row["decode_tokens_per_s"]
               / max(base_row["decode_tokens_per_s"], 1e-9))
    wall_speedup = paged_row["tokens_per_s"] / max(
        base_row["tokens_per_s"], 1e-9)
    tokens_equal = paged_tok == base_tok
    suite["rows"].append({
        "load": f"burst{LOAD_BURST}", "rate_req_s": None,
        "single_stream": base_row, "paged": paged_row,
        "paged_decode_speedup": speedup,
        "paged_wall_speedup": wall_speedup,
        "tokens_equal_oracle": tokens_equal})

    # Poisson rows: rates relative to the measured single-stream service
    # capacity (machine-adaptive, seeded arrival patterns).  An explicit
    # --profile overrides the request mix (count, prefix share, tenants,
    # seed, and — when it sets one — the arrival rate); prompt/gen are
    # pinned to the bench geometry the engine pool was warmed for.
    service_rate = LOAD_BURST / base_row["wall_s"]        # req/s
    for tag, factor in (("underload", 0.75), ("overload", 1.5)):
        rate = factor * service_rate
        if profile is not None:
            prof = dataclasses.replace(
                profile, name=f"{profile.name}_{tag}",
                arrival_rate=profile.arrival_rate or rate,
                prompt_len=LOAD_PROMPT, max_new_tokens=LOAD_GEN)
        else:
            prof = _poisson_profile(tag, rate, seed)
        for name, runner in (("single_stream",
                              lambda rq: _single_stream(model, fns,
                                                        params, rq)),
                             ("paged",
                              lambda rq: _paged(engine, params, rq))):
            reqs = prof.requests(cfg.vocab_size,
                                 page_size=pcfg.page_size)
            row, _ = runner(reqs)
            suite["rows"].append({"load": prof.name,
                                  "rate_req_s": prof.arrival_rate,
                                  "profile": prof.to_dict(),
                                  "path": name, **row})

    suite["verdict"] = {
        "paged_2x_at_8_concurrent": speedup >= 2.0,
        "tokens_equal_oracle": tokens_equal,
    }

    suite["rows"].append(_bench_prefix(cfg, model, params))
    prow = suite["rows"][-1]
    suite["verdict"]["batched_admission_1p5x"] = \
        prow["admission_speedup"] >= 1.5
    suite["verdict"]["prefix_tokens_equal_serial"] = prow["tokens_equal"]

    suite["rows"].append(_bench_tenants(cfg, model, params))
    trow = suite["rows"][-1]
    suite["verdict"]["tenant_p95_isolated"] = trow["p95_isolated"]
    suite["verdict"]["tenant_svc_never_preempted"] = \
        trow["svc_preempted_all_iters"] == 0

    suite["rows"].append(_bench_oversubscribed(cfg, model, params))
    orow = suite["rows"][-1]
    suite["verdict"]["oversubscribed_tokens_equal"] = \
        orow["tokens_equal"] and orow["preemptions"] >= 1 \
        and orow["all_finished"]

    suite["rows"].append(_bench_chaos(cfg, model, params))
    crow = suite["rows"][-1]
    suite["verdict"]["chaos_tokens_equal"] = \
        crow["tokens_equal"] and crow["all_finished"] \
        and crow["faults_fired"] >= 2
    suite["verdict"]["chaos_overhead_bounded"] = \
        crow["chaos_overhead"] <= CHAOS_OVERHEAD_MAX
    return suite


# Resource-manager row geometry.  Tenant row: a 6-slot engine whose pool
# holds four whole lifetimes, split half/half between a weight-2 service
# tenant (spaced requests) and a weight-1 batch tenant (8-burst at t=0).
# Budgets sum to the pool, so neither tenant's growth can even reach the
# other's pages — svc isolation is structural, and the row measures that
# the *scheduling* layer (shared segments + admission dispatches) keeps
# its p95 within 1.5x of a solo run.
TEN_SLOTS = 6
TEN_SVC_N = 3
TEN_BATCH_N = 8


def _bench_tenants(cfg, model, params) -> dict:
    from repro.serving import (PagedCacheConfig, PagedServingEngine,
                               TenantConfig)
    from repro.serving.paged_cache import (preferred_page_size,
                                           preferred_segment_len)

    cap_tokens = LOAD_PROMPT + LOAD_GEN + 1
    page_size = preferred_page_size(cfg, TEN_SLOTS, cap_tokens)
    blocks = -(-cap_tokens // page_size)
    pcfg = PagedCacheConfig(page_size=page_size,
                            n_pages=4 * blocks + 1,
                            max_slots=TEN_SLOTS, max_blocks=blocks,
                            segment_len=preferred_segment_len(
                                cfg, TEN_SLOTS, cap_tokens))
    tenants = [TenantConfig("svc", weight=2.0, page_budget=2 * blocks),
               TenantConfig("batch", weight=1.0, page_budget=2 * blocks)]
    engine = PagedServingEngine(model, pcfg, tenants=tenants)

    def svc_reqs(arrivals):
        reqs = _load_requests(cfg, TEN_SVC_N, seed=3)
        for r, a in zip(reqs, arrivals):
            r.tenant = "svc"
            r.arrival = a
        return reqs

    def batch_reqs():
        reqs = _load_requests(cfg, TEN_BATCH_N, seed=4)
        for r in reqs:
            r.tenant = "batch"
        return reqs

    # warm every dispatch shape first (the calibration below must see
    # steady-state latency, not compile time), then set the svc arrival
    # spacing off a warmed single-request run — the pattern stays
    # identical between the solo and contended runs
    engine.run(svc_reqs([0.0])[:1], params)
    engine.run(svc_reqs([0.0] * TEN_SVC_N) + batch_reqs(), params)
    cal = svc_reqs([0.0])[:1]
    engine.run(cal, params)
    spacing = 1.2 * (cal[0].t_done - cal[0].arrival)
    arrivals = [i * spacing for i in range(TEN_SVC_N)]
    engine.run(svc_reqs(arrivals) + batch_reqs(), params)   # warm burst

    # the SLO gate reads measured end-to-end latency from the engine's
    # telemetry records (result()["requests"]), filtered by tenant —
    # both sides run with telemetry enabled so the ratio is apples-to-
    # apples
    def p95(run_stats, tenant):
        lat = [rec["e2e_s"] for rec in run_stats["requests"]
               if rec["tenant"] == tenant and rec["e2e_s"] is not None]
        return float(np.percentile(lat, 95))

    solo = multi = None
    stats = None
    svc_preempted_any = 0       # summed over ALL contended runs: the
    for _ in range(ITERS):      # isolation gate must not miss a flaky
        s_reqs = svc_reqs(arrivals)     # preemption in a non-best iter
        s_stats = engine.run(s_reqs, params, obs=_fresh_obs())
        solo = min(solo, p95(s_stats, "svc")) if solo is not None \
            else p95(s_stats, "svc")
        m_stats = engine.run(svc_reqs(arrivals) + batch_reqs(), params,
                             obs=_fresh_obs())
        svc_preempted_any += m_stats["tenants"]["svc"]["preempted"]
        cur = p95(m_stats, "svc")
        if multi is None or cur < multi:
            multi, stats = cur, m_stats
    return {
        "load": "tenants2",
        "prompt_len": LOAD_PROMPT, "gen": LOAD_GEN,
        "page_size": page_size, "segment_len": pcfg.segment_len,
        "pool_pages": 4 * blocks,
        "svc_budget_pages": 2 * blocks, "batch_budget_pages": 2 * blocks,
        "svc_arrival_spacing_s": spacing,
        "svc_p95_solo_s": solo,
        "svc_p95_contended_s": multi,
        "svc_p95_ratio": multi / max(solo, 1e-9),
        "p95_isolated": multi <= 1.5 * solo,
        "svc_preempted_all_iters": svc_preempted_any,
        "preemptions": stats["preemptions"],
        "restores": stats["restores"],
        "pages_grown": stats["pages_grown"],
        "tenants": stats["tenants"],
        "metrics": stats["metrics"],
    }


# Oversubscribed row: four requests whose lifetimes need 4 pages each on
# a pool of 4 x 3 — admissions all fit (3 pages under growth-on-demand),
# the lifetimes cannot, so finishing requires at least one preempt/
# restore cycle.  The gate is the resource manager's acceptance
# criterion: bit-identical tokens to the unconstrained run.
OS_N = 4


def _bench_oversubscribed(cfg, model, params) -> dict:
    from repro.serving import PagedCacheConfig, PagedServingEngine
    from repro.serving.paged_cache import (preferred_page_size,
                                           preferred_segment_len)

    cap_tokens = LOAD_PROMPT + LOAD_GEN + 1
    page_size = preferred_page_size(cfg, OS_N, cap_tokens)
    segment_len = preferred_segment_len(cfg, OS_N, cap_tokens)
    blocks = -(-cap_tokens // page_size)
    admit_blocks = -(-min(LOAD_PROMPT + segment_len + 1, cap_tokens)
                     // page_size)
    if admit_blocks >= blocks:       # degenerate geometry: force pressure
        admit_blocks = blocks - 1
    mk_pcfg = lambda pages: PagedCacheConfig(  # noqa: E731
        page_size=page_size, n_pages=pages, max_slots=OS_N,
        max_blocks=blocks, segment_len=segment_len)
    big = PagedServingEngine(model, mk_pcfg(OS_N * blocks + 1))
    small = PagedServingEngine(model,
                               mk_pcfg(OS_N * admit_blocks + 1))
    for eng in (big, small):         # warm every shape, untimed
        eng.run(_load_requests(cfg, OS_N, seed=5), params)

    best_u = best_s = None
    tok_u = tok_s = stats_s = None
    for _ in range(ITERS):
        ru = _load_requests(cfg, OS_N, seed=5)
        su = big.run(ru, params)
        if best_u is None or su["wall_s"] < best_u:
            best_u, tok_u = su["wall_s"], {r.rid: list(r.tokens)
                                           for r in ru}
        rs = _load_requests(cfg, OS_N, seed=5)
        ss = small.run(rs, params)
        if best_s is None or ss["wall_s"] < best_s:
            best_s, tok_s, stats_s = ss["wall_s"], \
                {r.rid: list(r.tokens) for r in rs}, ss
    return {
        "load": "oversubscribed",
        "prompt_len": LOAD_PROMPT, "gen": LOAD_GEN,
        "page_size": page_size, "segment_len": segment_len,
        "pool_pages": OS_N * admit_blocks,
        "lifetime_pages_demand": OS_N * blocks,
        "wall_unconstrained_s": best_u,
        "wall_oversubscribed_s": best_s,
        "swap_overhead": best_s / max(best_u, 1e-9),
        "preemptions": stats_s["preemptions"],
        "restores": stats_s["restores"],
        "pages_swapped_out": stats_s["pages_swapped_out"],
        "pages_swapped_in": stats_s["pages_swapped_in"],
        "n_restore_dispatches": stats_s["n_restore_dispatches"],
        "free_low_water": stats_s["free_low_water"],
        "all_finished": stats_s["n_finished"] == OS_N,
        "tokens_equal": tok_s == tok_u,
        "tenants": stats_s["tenants"],
    }


# Chaos row: the self-healing acceptance measurement.  The same
# undersized geometry as the oversubscribed row runs once fault-free and
# once under a fixed-seed FaultPlan that injects an allocation failure
# and a poisoned decode segment (NaN logits) mid-run.  The gates are the
# recovery layer's contract (serving/recovery.py): every request still
# finishes with tokens bit-identical to the fault-free run, nothing
# dead-letters under the default retry policy, and the wall cost of
# healing (rollback + backoff + restore) stays within a bounded multiple
# of the clean run.
CHAOS_OVERHEAD_MAX = 5.0


def _bench_chaos(cfg, model, params) -> dict:
    from repro.serving import (FaultPlan, PagedCacheConfig,
                               PagedServingEngine, RecoveryPolicy)
    from repro.serving.paged_cache import (preferred_page_size,
                                           preferred_segment_len)

    cap_tokens = LOAD_PROMPT + LOAD_GEN + 1
    page_size = preferred_page_size(cfg, OS_N, cap_tokens)
    segment_len = preferred_segment_len(cfg, OS_N, cap_tokens)
    blocks = -(-cap_tokens // page_size)
    admit_blocks = -(-min(LOAD_PROMPT + segment_len + 1, cap_tokens)
                     // page_size)
    if admit_blocks >= blocks:
        admit_blocks = blocks - 1
    pcfg = PagedCacheConfig(page_size=page_size,
                            n_pages=OS_N * admit_blocks + 1,
                            max_slots=OS_N, max_blocks=blocks,
                            segment_len=segment_len)
    engine = PagedServingEngine(model, pcfg)
    # the boundary invariant audit runs armed in the smoke: CI exercises
    # the checker itself, and anything it flags fails the token gate
    policy = RecoveryPolicy(check_invariants=True)
    # a FaultPlan is stateful (opportunity counters), so each run gets a
    # fresh copy of the same schedule — that IS the reproducibility
    mk_plan = lambda: FaultPlan.at(alloc=1, decode_poison=1)  # noqa: E731
    engine.run(_load_requests(cfg, OS_N, seed=5), params,
               recovery=policy)                               # warm
    engine.run(_load_requests(cfg, OS_N, seed=5), params,
               faults=mk_plan(),
               recovery=policy)         # warm the recovery path shapes

    best_c = best_f = None
    tok_c = tok_f = stats_f = obs_f = None
    for _ in range(ITERS):
        rc = _load_requests(cfg, OS_N, seed=5)
        sc = engine.run(rc, params, recovery=policy, obs=_fresh_obs())
        if best_c is None or sc["wall_s"] < best_c:
            best_c, tok_c = sc["wall_s"], {r.rid: list(r.tokens)
                                           for r in rc}
        rf = _load_requests(cfg, OS_N, seed=5)
        obs = _fresh_obs()
        sf = engine.run(rf, params, faults=mk_plan(), recovery=policy,
                        obs=obs)
        if best_f is None or sf["wall_s"] < best_f:
            best_f, tok_f, stats_f, obs_f = sf["wall_s"], \
                {r.rid: list(r.tokens) for r in rf}, sf, obs
    # the acceptance artifact: Prometheus + JSONL exports of the best
    # faulted run, with every fire attributable to a request span
    import os

    try:
        from benchmarks.common import RESULTS_DIR
    except ImportError:
        from common import RESULTS_DIR
    exports = obs_f.export(os.path.join(RESULTS_DIR, "chaos_telemetry"))
    return {
        "load": "chaos",
        "prompt_len": LOAD_PROMPT, "gen": LOAD_GEN,
        "page_size": page_size, "segment_len": segment_len,
        "pool_pages": OS_N * admit_blocks,
        "check_invariants": True,
        "wall_clean_s": best_c,
        "wall_chaos_s": best_f,
        "chaos_overhead": best_f / max(best_c, 1e-9),
        "faults_fired": len(stats_f["faults"]["fired"]),
        "faults": stats_f["faults"],
        "recovery": stats_f["recovery"],
        "invariant_violations": stats_f["recovery"].get(
            "invariant_violations", []),
        "all_finished": stats_f["n_finished"] == OS_N,
        "dead_lettered": stats_f["n_dead_lettered"],
        "dead_letter_records": stats_f["recovery"].get(
            "dead_letter_records", []),
        "tokens_equal": tok_f == tok_c,
        "metrics": stats_f["metrics"],
        "telemetry": {
            "exports": exports,
            "n_trace_events": len(obs_f.tracer.events),
            "faults_attributed": _faults_attributed(obs_f, stats_f),
        },
    }


def _faults_attributed(obs, stats) -> bool:
    """Every fired engine fault site must show up as a FAULT trace event,
    and every QUARANTINE/DEAD_LETTER must name a request and join back to
    a FAULT at the same site within one boundary (decode faults fire
    inside the segment and surface at its closing boundary)."""
    ev = obs.tracer.events
    fault_keys = {(e.detail["site"], e.boundary) for e in ev
                  if e.kind == "FAULT"}
    fired = {site for site, _ in stats["faults"]["fired"]}
    if not fired <= {s for s, _ in fault_keys}:
        return False
    for e in ev:
        if e.kind in ("QUARANTINE", "DEAD_LETTER"):
            if e.rid is None or not any(
                    (e.detail["site"], b) in fault_keys
                    for b in (e.boundary - 1, e.boundary)):
                return False
    return True


# Cluster row: replicated serving under replica loss.  An 8-request
# shared-prefix burst goes through the FrontDoor of a 3-replica
# ServingCluster three ways: single-engine oracle, fault-free cluster
# (tokens must be bit-identical — routing is invisible), and a chaos
# pass with the loaded replica crashed mid-burst (every request must
# finish bit-identical or dead-letter with a typed ReplicaLost, and no
# surviving replica may leak a page).  Affinity hit-rate is reported:
# the shared prefix should concentrate the burst on the replica that
# admitted it first.
CLUSTER_REPLICAS = 3


def _bench_cluster(cfg, model, params) -> dict:
    from repro.serving import (FaultPlan, PagedCacheConfig,
                               PagedServingEngine, ReplicaLost,
                               ServingCluster)
    from repro.serving.paged_cache import (preferred_page_size,
                                           preferred_segment_len)

    cap_tokens = PREFIX_PROMPT + PREFIX_GEN + 1
    page_size = min(preferred_page_size(cfg, LOAD_SLOTS, cap_tokens),
                    PREFIX_TARGET)
    blocks = -(-cap_tokens // page_size)
    pcfg = PagedCacheConfig(page_size=page_size,
                            n_pages=LOAD_SLOTS * blocks + 1,
                            max_slots=LOAD_SLOTS, max_blocks=blocks,
                            segment_len=preferred_segment_len(
                                cfg, LOAD_SLOTS, cap_tokens),
                            retain_pages=PREFIX_TARGET // page_size)
    engine = PagedServingEngine(model, pcfg)
    # single-engine oracle (also warms every compiled shape the replica
    # runs reuse — replicas multiply run-state, not compilations)
    _, oracle = _prefix_requests(cfg, pcfg, LOAD_BURST, seed=21)
    t0 = time.perf_counter()
    engine.run(oracle, params)
    wall_single = time.perf_counter() - t0
    base = {r.rid: list(r.tokens) for r in oracle}

    # fault-free cluster pass: routing must be invisible in the tokens.
    # Two waves through one cluster: the first lands on cold tries (the
    # whole burst routes before any replica admits, so it spreads
    # least-loaded); the second measures prefix affinity against the
    # retention-pinned tries the first wave warmed.
    cl_clean = ServingCluster(engine, params, n_replicas=CLUSTER_REPLICAS)
    prefix_len, reqs_c = _prefix_requests(cfg, pcfg, LOAD_BURST, seed=21)
    t0 = time.perf_counter()
    out_c = cl_clean.run(reqs_c)
    wall_clean = time.perf_counter() - t0
    fd_cold = dict(out_c["front_door"])
    _, reqs_w = _prefix_requests(cfg, pcfg, LOAD_BURST, seed=21)
    out_c = cl_clean.run(reqs_w)
    fd_warm = out_c["front_door"]
    warm_routed = fd_warm["routed"] - fd_cold["routed"]
    warm_hits = fd_warm["affinity_hits"] - fd_cold["affinity_hits"]
    tokens_equal_single = \
        {r.rid: list(r.tokens) for r in reqs_c} == base \
        and {r.rid: list(r.tokens) for r in reqs_w} == base

    # chaos pass: kill whichever replica the affinity routing loaded, at
    # its round-2 probe (opportunity CLUSTER_REPLICAS = r0 on round 2 —
    # affinity concentrates the shared-prefix burst on r0), mid-burst
    cl = ServingCluster(engine, params, n_replicas=CLUSTER_REPLICAS,
                        faults=FaultPlan.at(
                            replica_crash=CLUSTER_REPLICAS))
    _, reqs_f = _prefix_requests(cfg, pcfg, LOAD_BURST, seed=21)
    t0 = time.perf_counter()
    out_f = cl.run(reqs_f)
    wall_chaos = time.perf_counter() - t0
    chaos_ok = all(
        (list(r.tokens) == base[r.rid]) if r.failure is None
        else isinstance(r.failure, ReplicaLost) for r in reqs_f)
    leaks = []
    for rep in cl.replicas:
        if rep.fenced:
            continue
        s = rep.run.sched.rm.stats()
        if s["free_pages"] + s["pinned_pages"] \
                != pcfg.allocatable_pages \
                or s["held_pages"] != s["pinned_pages"]:
            leaks.append({"replica": rep.name,
                          "free": s["free_pages"],
                          "held": s["held_pages"],
                          "pinned": s["pinned_pages"]})
    return {
        "load": f"cluster{CLUSTER_REPLICAS}",
        "n_replicas": CLUSTER_REPLICAS,
        "burst": LOAD_BURST,
        "prefix_len": prefix_len, "prompt_len": PREFIX_PROMPT,
        "page_size": page_size,
        "wall_single_s": wall_single,
        "wall_clean_s": wall_clean,
        "wall_chaos_s": wall_chaos,
        "tokens_equal_single": tokens_equal_single,
        "clean_finished": out_c["n_finished"],
        "clean_dead_lettered": out_c["n_dead_lettered"],
        "affinity": {**fd_warm,
                     "warm_wave_hits": warm_hits,
                     "warm_wave_routed": warm_routed,
                     "affinity_rate": (warm_hits / warm_routed
                                       if warm_routed else 0.0)},
        "crash_fired": out_f["faults"]["fired"] == [["replica_crash",
                                                     CLUSTER_REPLICAS]],
        "replica_states": {k: v["state"]
                           for k, v in out_f["replicas"].items()},
        "n_migrated": out_f["n_migrated"],
        "n_restarted": out_f["n_restarted"],
        "chaos_finished": out_f["n_finished"],
        "chaos_dead_lettered": out_f["n_dead_lettered"],
        "dead_letter_records": out_f["dead_letter_records"],
        "chaos_ok": chaos_ok,
        "survivor_leaks": leaks,
        "survivors_drained": not leaks,
    }


# Shared-prefix admission row geometry: a system prompt worth several
# pages plus a short distinct user suffix per request — the workload the
# prefix cache exists for.  The prefix is aligned down to whole pages of
# the tuned page size at request-build time.
PREFIX_PROMPT = 56
PREFIX_TARGET = 48              # nominal system-prompt length
PREFIX_GEN = LOAD_GEN


def _prefix_requests(cfg, pcfg, n, seed):
    """``n`` requests sharing a page-aligned common system-prompt prefix
    with distinct user tails."""
    from repro.data.synthetic import lm_tokens
    from repro.serving import Request
    ps = pcfg.page_size
    prefix_len = (PREFIX_TARGET // ps) * ps or min(ps, PREFIX_PROMPT - 8)
    prefix = np.asarray(lm_tokens(prefix_len, cfg.vocab_size,
                                  seed=seed)).astype(np.int32)
    tails = np.asarray(
        lm_tokens(n * (PREFIX_PROMPT - prefix_len), cfg.vocab_size,
                  seed=seed + 1)).reshape(n, -1).astype(np.int32)
    return prefix_len, [
        Request(rid=i,
                prompt=np.concatenate([prefix, tails[i]]),
                max_new_tokens=PREFIX_GEN)
        for i in range(n)]


def _bench_prefix(cfg, model, params) -> dict:
    """Shared-prefix admission row: batched+sharing vs PR-3 serial."""
    from repro.serving import PagedCacheConfig, PagedServingEngine
    from repro.serving.paged_cache import (preferred_page_size,
                                           preferred_segment_len)

    cap_tokens = PREFIX_PROMPT + PREFIX_GEN + 1
    # tuned page size, capped so the pool can express the shared prefix
    # at page granularity (a geometric constraint, not a tuning override)
    page_size = min(preferred_page_size(cfg, LOAD_SLOTS, cap_tokens),
                    PREFIX_TARGET)
    blocks = -(-cap_tokens // page_size)
    pcfg = PagedCacheConfig(page_size=page_size,
                            n_pages=LOAD_SLOTS * blocks + 1,
                            max_slots=LOAD_SLOTS, max_blocks=blocks,
                            segment_len=preferred_segment_len(
                                cfg, LOAD_SLOTS, cap_tokens))
    engines = {
        "serial": PagedServingEngine(model, pcfg, prefill_mode="serial"),
        "batched": PagedServingEngine(model, pcfg,
                                      prefill_mode="batched"),
    }
    prefix_len, _ = _prefix_requests(cfg, pcfg, LOAD_BURST, seed=21)
    # one untimed run per engine visits every prefill shape it will
    # compile (serial: per page count; batched: per suffix bucket)
    for eng in engines.values():
        _, warm = _prefix_requests(cfg, pcfg, LOAD_BURST, seed=21)
        eng.run(warm, params)

    best: dict = {}
    tokens: dict = {}
    for name, eng in engines.items():
        for _ in range(ITERS):
            _, reqs = _prefix_requests(cfg, pcfg, LOAD_BURST, seed=21)
            stats = eng.run(reqs, params)
            if name not in best or stats["prefill_s"] < \
                    best[name]["prefill_s"]:
                best[name] = stats
                tokens[name] = {r.rid: list(r.tokens) for r in reqs}

    b, s = best["batched"], best["serial"]
    unshared_pages = LOAD_BURST * pcfg.pages_for(cap_tokens)
    return {
        "load": f"shared_prefix{LOAD_BURST}",
        "prefix_len": prefix_len,
        "prompt_len": PREFIX_PROMPT,
        "page_size": page_size,
        "admission_prefill_serial_s": s["prefill_s"],
        "admission_prefill_batched_s": b["prefill_s"],
        "admission_speedup": s["prefill_s"] / max(b["prefill_s"], 1e-9),
        "prefill_dispatches_serial": s["n_prefill_dispatches"],
        "prefill_dispatches_batched": b["n_prefill_dispatches"],
        "prefix_hit_rate": (b["prefix_hits"]
                            / max(b["prefix_lookups"], 1)),
        "prefix_tokens_matched": b["prefix_tokens_matched"],
        "pages_allocated": b["pages_allocated_total"],
        "pages_saved": unshared_pages - b["pages_allocated_total"],
        "tokens_equal": tokens["batched"] == tokens["serial"],
    }


def main(argv=None):
    import argparse
    import json

    ap = argparse.ArgumentParser(
        description="serving-path benchmark suite")
    ap.add_argument("--seed", type=int, default=POISSON_SEED,
                    help="profile seed for the Poisson load rows "
                         "(prompts = seed, arrivals = seed + 1)")
    ap.add_argument("--profile", type=str, default=None,
                    help="path to a TrafficProfile JSON "
                         "(serving/traffic.py) overriding the Poisson "
                         "rows' request mix — the SERVE design-flow "
                         "task's stage-2 scorer and CI share this "
                         "entry point")
    # run.py invokes main() programmatically: only a __main__ launch
    # (which passes sys.argv[1:] explicitly) reads the command line
    args = ap.parse_args(argv if argv is not None else [])
    profile = None
    if args.profile:
        from repro.serving.traffic import TrafficProfile
        with open(args.profile) as f:
            profile = TrafficProfile.from_dict(json.load(f))

    results = {"backend": jax.default_backend(), "t": time.time(),
               "shapes": []}
    for arch, batch, prompt_len, gen in SERVE_SHAPES:
        row = _bench_shape(arch, batch, prompt_len, gen)
        results["shapes"].append(row)
        tag = f"serve_{row['arch']}"
        emit(f"{tag}_prefill", row["loop_jnp"]["prefill_s"] * 1e6,
             f"prefill_tok_s="
             f"{row['loop_jnp']['prefill_tokens_per_s']:.1f}")
        for name in VARIANTS:
            v = row[name]
            derived = f"decode_tok_s={v['decode_tokens_per_s']:.1f}"
            if name != "loop_jnp":
                derived += (f";vs_loop_jnp="
                            f"{v['speedup_vs_loop_jnp']:.2f}x")
            emit(f"{tag}_decode_{name}", v["decode_s"] * 1e6, derived)
        emit(f"{tag}_verdict", 0.0,
             f"not_slower_than_seed={int(row['not_slower_than_seed'])};"
             f"samples_agree={int(row['samples_agree'])}")

    load = _bench_load(profile=profile, seed=args.seed)
    results["load"] = load
    for r in load["rows"]:
        if "paged_decode_speedup" in r:
            emit(f"serve_load_{r['load']}_paged",
                 r["paged"]["wall_s"] * 1e6,
                 f"decode_tok_s={r['paged']['decode_tokens_per_s']:.1f};"
                 f"vs_single_stream={r['paged_decode_speedup']:.2f}x;"
                 f"wall={r['paged_wall_speedup']:.2f}x;"
                 f"p95_s={r['paged']['latency_p95_s']:.3f};"
                 f"tokens_equal={int(r['tokens_equal_oracle'])}")
            emit(f"serve_load_{r['load']}_single_stream",
                 r["single_stream"]["wall_s"] * 1e6,
                 f"decode_tok_s="
                 f"{r['single_stream']['decode_tokens_per_s']:.1f};"
                 f"p95_s={r['single_stream']['latency_p95_s']:.3f}")
        elif "admission_speedup" in r:
            emit(f"serve_load_{r['load']}_admission",
                 r["admission_prefill_batched_s"] * 1e6,
                 f"vs_serial={r['admission_speedup']:.2f}x;"
                 f"hit_rate={r['prefix_hit_rate']:.2f};"
                 f"pages_saved={r['pages_saved']};"
                 f"tokens_equal={int(r['tokens_equal'])}")
        elif r["load"] == "tenants2":
            emit("serve_load_tenants2_svc_p95",
                 r["svc_p95_contended_s"] * 1e6,
                 f"vs_solo={r['svc_p95_ratio']:.2f}x;"
                 f"isolated={int(r['p95_isolated'])};"
                 f"svc_preempted={r['tenants']['svc']['preempted']};"
                 f"batch_preempted="
                 f"{r['tenants']['batch']['preempted']};"
                 f"batch_restored={r['tenants']['batch']['restored']}")
        elif r["load"] == "oversubscribed":
            emit("serve_load_oversubscribed",
                 r["wall_oversubscribed_s"] * 1e6,
                 f"overhead={r['swap_overhead']:.2f}x;"
                 f"preemptions={r['preemptions']};"
                 f"pages_swapped={r['pages_swapped_out']};"
                 f"tokens_equal={int(r['tokens_equal'])}")
        elif r["load"] == "chaos":
            # dead letters surface as structured (site, tenant, retries)
            # records, not a bare count — an empty list is the pass state
            dl = ",".join(f"{d['site']}@{d['tenant']}x{d['retries']}"
                          for d in r["dead_letter_records"]) or "none"
            emit("serve_load_chaos", r["wall_chaos_s"] * 1e6,
                 f"overhead={r['chaos_overhead']:.2f}x;"
                 f"faults_fired={r['faults_fired']};"
                 f"quarantines={r['recovery']['quarantines']};"
                 f"dead_letters={dl};"
                 f"tokens_equal={int(r['tokens_equal'])}")
        else:
            emit(f"serve_load_{r['load']}_{r['path']}",
                 r["wall_s"] * 1e6,
                 f"rate={r['rate_req_s']:.2f}req_s;"
                 f"tok_s={r['tokens_per_s']:.1f};"
                 f"p50_s={r['latency_p50_s']:.3f};"
                 f"p95_s={r['latency_p95_s']:.3f}")
    save_json("serve_bench.json", results)
    # the speed verdict gates CI, it is not just an artifact field.
    # samples_agree is reported but not gated: greedy argmax can
    # legitimately flip on float-reduction-order ties between the kernel
    # and the oracle — numerical equivalence is pinned (with tolerances)
    # by tests/test_decode_kernel.py, the right tool for that claim.
    slow = [r["arch"] for r in results["shapes"]
            if not r["not_slower_than_seed"]]
    if slow:
        raise SystemExit(f"serve bench regression on {slow}: the scan'd "
                         f"flash-decode path must never be slower than "
                         f"the seed Python-loop jnp path")
    verdict = load["verdict"]
    if not verdict["tokens_equal_oracle"]:
        # Gated (unlike samples_agree above): the acceptance criterion
        # for the paged engine is token-identical generation, and both
        # sides run the same jnp attention math (the paged path's extra
        # masked slots contribute exact zeros to the softmax sums).  A
        # residual flake mode exists — a near-tie in top-2 logits plus a
        # batch-8-vs-batch-1 reduction-grouping difference could flip one
        # argmax — so if this trips on an unchanged tree, diff the
        # per-request token grids in the JSON artifact before suspecting
        # the engine.
        raise SystemExit("paged engine tokens diverged from the "
                         "contiguous jnp-oracle scan path (see "
                         "benchmarks/results/serve_bench.json load row)")
    if not verdict["paged_2x_at_8_concurrent"]:
        raise SystemExit("continuous-batching paged decode fell below "
                         "2x single-stream aggregate decode tokens/s at "
                         f"{LOAD_BURST} concurrent requests")
    if not verdict["prefix_tokens_equal_serial"]:
        raise SystemExit("shared-prefix engine tokens diverged from the "
                         "serial non-shared admission path (see "
                         "benchmarks/results/serve_bench.json "
                         "shared_prefix row)")
    if not verdict["batched_admission_1p5x"]:
        raise SystemExit("batched ragged admission prefill fell below "
                         "1.5x the serial batch-1 path for the "
                         f"{LOAD_BURST}-request shared-prefix burst")
    if not verdict["oversubscribed_tokens_equal"]:
        raise SystemExit(
            "oversubscribed row failed: requests must all finish with "
            ">= 1 preempt/restore cycle and tokens bit-identical to the "
            "unconstrained run (see serve_bench.json oversubscribed row)")
    if not verdict["chaos_tokens_equal"]:
        raise SystemExit(
            "chaos row failed: with an injected allocation failure and a "
            "poisoned decode segment, every request must still finish "
            "with tokens bit-identical to the fault-free run (see "
            "serve_bench.json chaos row)")
    if not verdict["chaos_overhead_bounded"]:
        raise SystemExit(
            "chaos row failed: self-healing wall overhead exceeded "
            f"{CHAOS_OVERHEAD_MAX}x the fault-free run (see "
            "serve_bench.json chaos row)")
    if not (verdict["tenant_p95_isolated"]
            and verdict["tenant_svc_never_preempted"]):
        raise SystemExit(
            "tenant isolation row failed: the quota-protected tenant's "
            "p95 must stay within 1.5x of its solo run and it must "
            "never be preempted by the bursting tenant (see "
            "serve_bench.json tenants2 row)")
    return results


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
