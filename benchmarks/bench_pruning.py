"""Paper Fig. 3 + Fig. 4: auto-pruning search trajectory and per-candidate
resource utilization, for Jet-DNN and ResNet9.

Emits the per-step (rate, accuracy, resource) curves the figures plot,
as CSV rows + benchmarks/results/pruning_curves.json.
"""

from __future__ import annotations

from repro.core.metamodel import MetaModel
from repro.core.strategies import pruning_strategy

try:
    from benchmarks.common import emit, save_json
except ImportError:  # run as a script
    from common import emit, save_json


def run(model: str = "jet_dnn", samples: int = 2048, epochs: int = 2):
    meta = MetaModel({"ModelGen.train_samples": samples,
                      "ModelGen.train_epochs": 4})
    flow = pruning_strategy(model, train_epochs=epochs)
    meta = flow.execute(meta)
    probes = meta.trace("pruning.probe")
    res = meta.get("pruning.result")
    curve = []
    for i, p in enumerate(probes):
        row = {"step": i + 1, "rate": p.get("rate"),
               "accuracy": p.get("accuracy"),
               "macs_fraction": p.get("macs_fraction"),
               "weight_bits": p.get("weight_bits"),
               "feasible": p.get("feasible", True)}
        curve.append(row)
        emit(f"fig3_{model}_s{i+1}",
             0.0,
             f"rate={row['rate']:.3f};acc={row['accuracy']:.4f};"
             f"macs={row['macs_fraction'] if row['macs_fraction'] is not None else 1.0}")
    summary = {"model": model, "curve": curve,
               "final_rate": res["pruning_rate"],
               "final_accuracy": res["accuracy"],
               "base_accuracy": res["base_accuracy"],
               "macs_fraction": res["macs_fraction"],
               "search_steps": res["search_steps"]}
    emit(f"fig4_{model}_final", 0.0,
         f"rate={res['pruning_rate']:.3f};"
         f"dsp_analogue_reduction={1 - res['macs_fraction']:.3f}")
    return summary


def main(models=("jet_dnn", "resnet9")):
    out = {}
    for m in models:
        # resnet9 is heavier: fewer samples
        out[m] = run(m, samples=1024 if m != "jet_dnn" else 2048,
                     epochs=1 if m != "jet_dnn" else 2)
    save_json("pruning_curves.json", out)
    return out


if __name__ == "__main__":
    main()
