"""Paper Table II analogue: final comparison of optimization strategies on
Jet-DNN — accuracy vs resource proxies vs roofline-estimated latency.

FPGA columns -> TPU columns (DESIGN.md §2):
  DSP usage    -> effective MACs per sample (pruning/scaling-structural)
  LUT usage    -> weight storage bits (quantization + pruning)
  latency (ns) -> roofline-estimated inference time for batch-1 on one
                  v5e chip: max(2*MACs/peak_int8, weight_bytes/HBM_bw)

Rows: baseline (fp32, as generated), P-only, Q-only (alpha_q=1%),
S->P->Q (alpha_q=1%), S->P->Q (alpha_q=4%) — mirroring the paper's
"this work" rows.
"""

from __future__ import annotations

from repro.core.metamodel import MetaModel
from repro.core.strategies import (combined_strategy, pruning_strategy,
                                   quantization_strategy)

try:
    from benchmarks.common import emit, save_json
except ImportError:
    from common import emit, save_json

PEAK_INT8 = 394e12     # v5e int8 ops/s (2x bf16)
PEAK_BF16 = 197e12
HBM_BW = 819e9

CFG = {"ModelGen.train_samples": 2048, "ModelGen.train_epochs": 4,
       "Pruning.train_epochs": 2, "Scaling.train_epochs": 3,
       "Scaling.max_trials_num": 2, "Scaling.tolerate_acc_loss": 0.02}


def row_from(meta: MetaModel, label: str, int8: bool) -> dict:
    art = meta.latest("dnn")
    m = art.metrics
    macs = m.get("effective_macs", m.get("total_macs"))
    wbytes = m.get("weight_bits", 0) / 8
    peak = PEAK_INT8 if int8 else PEAK_BF16
    lat_ns = max(2 * macs / peak, wbytes / HBM_BW) * 1e9
    return {"strategy": label, "accuracy": m.get("accuracy"),
            "effective_macs": macs, "weight_bits": m.get("weight_bits"),
            "roofline_latency_ns": lat_ns}


def main(model: str = "jet_dnn"):
    rows = []

    meta = MetaModel(dict(CFG))
    from repro.core.flow import DesignFlow
    from repro.tasks.model_gen import ModelGen
    DesignFlow("base").chain(ModelGen(model=model))
    f = DesignFlow("base")
    f.chain(ModelGen(model=model))
    meta = f.execute(meta)
    rows.append(row_from(meta, "baseline-fp32", int8=False))

    meta = pruning_strategy(model, train_epochs=2).execute(
        MetaModel(dict(CFG)))
    rows.append(row_from(meta, "P-only", int8=False))

    meta = quantization_strategy(model, tolerate_acc_loss=0.01).execute(
        MetaModel(dict(CFG)))
    rows.append(row_from(meta, "Q-only(a=1%)", int8=True))

    meta = combined_strategy(
        model, "SPQ",
        task_params={"Q": {"tolerate_acc_loss": 0.01}}).execute(
        MetaModel(dict(CFG)))
    rows.append(row_from(meta, "S-P-Q(a=1%)", int8=True))

    meta = combined_strategy(
        model, "SPQ",
        task_params={"Q": {"tolerate_acc_loss": 0.04}}).execute(
        MetaModel(dict(CFG)))
    rows.append(row_from(meta, "S-P-Q(a=4%)", int8=True))

    base = rows[0]
    for r in rows:
        emit(f"table2_{model}_{r['strategy']}", r["roofline_latency_ns"],
             f"acc={r['accuracy']:.4f};"
             f"macs_red={1 - r['effective_macs']/base['effective_macs']:.3f};"
             f"bits_red={1 - r['weight_bits']/base['weight_bits']:.3f}")
    save_json("table2.json", rows)
    return rows


if __name__ == "__main__":
    main()
