"""Cluster smoke suite: replicated serving under replica loss,
standalone.

Runs only ``bench_serve._bench_cluster`` — an 8-request shared-prefix
burst through a 3-replica :class:`ServingCluster` behind the
prefix-affinity FrontDoor, once fault-free and once with the loaded
replica crashed mid-burst via a fixed-seed ``replica_crash`` injection —
so CI can gate the failover contract without paying for the full serving
suite.  Gates: the fault-free cluster's tokens are bit-identical to
routing the same requests through one engine (routing is invisible);
under the crash every request finishes bit-identical or dead-letters
with a typed ReplicaLost; surviving replicas leak zero pages; the
injected crash actually fired.  Affinity hit-rate is reported.  Results
land in ``benchmarks/results/cluster_bench.json``.
"""

from __future__ import annotations

import time

import jax

try:
    from benchmarks.bench_serve import LOAD_ARCH, _bench_cluster
    from benchmarks.common import emit, save_json
except ImportError:
    from bench_serve import LOAD_ARCH, _bench_cluster
    from common import emit, save_json


def main():
    from repro.configs.registry import get_config
    from repro.models.api import build_model

    cfg = get_config(LOAD_ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    row = _bench_cluster(cfg, model, params)
    results = {"backend": jax.default_backend(), "t": time.time(),
               "cluster": row}
    dl = ",".join(f"{d['site']}@{d.get('replica', '-')}"
                  for d in row["dead_letter_records"]) or "none"
    emit("serve_load_cluster", row["wall_chaos_s"] * 1e6,
         f"replicas={row['n_replicas']};"
         f"affinity_rate={row['affinity']['affinity_rate']:.2f};"
         f"migrated={row['n_migrated']};"
         f"restarted={row['n_restarted']};"
         f"dead_letters={dl};"
         f"tokens_equal={int(row['tokens_equal_single'])};"
         f"chaos_ok={int(row['chaos_ok'])}")
    save_json("cluster_bench.json", results)
    if not row["tokens_equal_single"]:
        raise SystemExit(
            "cluster smoke failed: the fault-free 3-replica cluster must "
            "generate tokens bit-identical to routing the same requests "
            "through a single engine (see "
            "benchmarks/results/cluster_bench.json)")
    if not row["crash_fired"]:
        raise SystemExit(
            "cluster smoke failed: the fixed-seed replica_crash never "
            "fired — the chaos pass measured nothing")
    if not row["chaos_ok"]:
        raise SystemExit(
            "cluster smoke failed: with a replica crashed mid-burst, "
            "every request must finish bit-identical to the "
            "single-replica run or dead-letter with a typed ReplicaLost")
    if row["chaos_finished"] + row["chaos_dead_lettered"] \
            != row["burst"]:
        raise SystemExit(
            "cluster smoke failed: requests went missing — finished + "
            "dead-lettered must account for the whole burst")
    if not row["survivors_drained"]:
        raise SystemExit(
            "cluster smoke failed: surviving replicas leaked pages "
            f"after failover: {row['survivor_leaks']}")
    return results


if __name__ == "__main__":
    main()
