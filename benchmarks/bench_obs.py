"""Observability overhead suite: telemetry must be (near) free.

Replays the serving suite's burst-row geometry (``bench_serve``:
LOAD_BURST concurrent requests on the LOAD_SLOTS-slot paged engine)
twice per iteration on the same warmed engine — once with telemetry
disabled (the ServingPlan default: counters only, histograms/gauges
bound to NULL_METRIC, no tracer) and once fully enabled (histograms,
gauges, request-lifecycle tracing) — interleaved so machine drift hits
both sides equally.  The gate compares best-of-``ITERS`` walls:
enabled must stay within ``OBS_OVERHEAD_MAX`` of disabled.

A second row times the disabled-mode probe itself (the
``NULL_METRIC.observe`` no-op every gated instrument degrades to) in
nanoseconds per call — the "disabled mode costs one attribute lookup"
claim, measured.

The enabled run's Prometheus text export and JSONL trace land in
``benchmarks/results/obs_telemetry/`` (CI uploads them as artifacts);
rows land in ``benchmarks/results/obs_bench.json``.
"""

from __future__ import annotations

import os
import time

import jax

try:
    from benchmarks.bench_serve import (LOAD_ARCH, LOAD_BURST, LOAD_GEN,
                                        LOAD_PROMPT, LOAD_SLOTS,
                                        _fresh_obs, _load_requests)
    from benchmarks.common import RESULTS_DIR, emit, save_json
except ImportError:
    from bench_serve import (LOAD_ARCH, LOAD_BURST, LOAD_GEN,
                             LOAD_PROMPT, LOAD_SLOTS, _fresh_obs,
                             _load_requests)
    from common import RESULTS_DIR, emit, save_json

ITERS = 5
OBS_OVERHEAD_MAX = 1.03          # enabled wall <= 3% over disabled
PROBE_CALLS = 1_000_000


def _probe_ns() -> float:
    """Per-call cost of the disabled-mode no-op probe."""
    from repro.serving.observe import NULL_METRIC

    observe = NULL_METRIC.observe
    for _ in range(1000):        # warm
        observe(1.0, ("r0",))
    t0 = time.perf_counter()
    for _ in range(PROBE_CALLS):
        observe(1.0, ("r0",))
    return (time.perf_counter() - t0) / PROBE_CALLS * 1e9


def main():
    from repro.configs.registry import get_config
    from repro.models.api import build_model
    from repro.serving import PagedCacheConfig, PagedServingEngine
    from repro.serving.engine import warmup
    from repro.serving.paged_cache import (preferred_page_size,
                                           preferred_segment_len)

    cfg = get_config(LOAD_ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cap_tokens = LOAD_PROMPT + LOAD_GEN + 1
    page_size = preferred_page_size(cfg, LOAD_SLOTS, cap_tokens)
    blocks = -(-cap_tokens // page_size)
    pcfg = PagedCacheConfig(page_size=page_size,
                            n_pages=LOAD_SLOTS * blocks + 1,
                            max_slots=LOAD_SLOTS, max_blocks=blocks,
                            segment_len=preferred_segment_len(
                                cfg, LOAD_SLOTS, cap_tokens))
    engine = PagedServingEngine(model, pcfg)
    warmup(engine, params, LOAD_PROMPT, LOAD_GEN)
    engine.run(_load_requests(cfg, LOAD_BURST, seed=97), params)

    best_off = best_on = None
    obs_best = stats_best = None
    for _ in range(ITERS):
        r_off = _load_requests(cfg, LOAD_BURST, 1)
        s_off = engine.run(r_off, params)        # plan default: disabled
        if best_off is None or s_off["wall_s"] < best_off:
            best_off = s_off["wall_s"]
        r_on = _load_requests(cfg, LOAD_BURST, 1)
        obs = _fresh_obs()
        s_on = engine.run(r_on, params, obs=obs)
        if best_on is None or s_on["wall_s"] < best_on:
            best_on, obs_best, stats_best = s_on["wall_s"], obs, s_on
    overhead = best_on / max(best_off, 1e-9)
    exports = obs_best.export(os.path.join(RESULTS_DIR, "obs_telemetry"))
    probe_ns = _probe_ns()

    row = {
        "load": f"burst{LOAD_BURST}",
        "arch": cfg.name, "prompt_len": LOAD_PROMPT, "gen": LOAD_GEN,
        "slots": LOAD_SLOTS, "iters": ITERS,
        "wall_disabled_s": best_off,
        "wall_enabled_s": best_on,
        "obs_overhead": overhead,
        "obs_overhead_max": OBS_OVERHEAD_MAX,
        "disabled_probe_ns": probe_ns,
        "n_trace_events": len(obs_best.tracer.events),
        "metrics": stats_best["metrics"],
        "exports": exports,
    }
    results = {"backend": jax.default_backend(), "t": time.time(),
               "obs": row}
    emit("serve_obs_overhead", best_on * 1e6,
         f"vs_disabled={overhead:.4f}x;"
         f"trace_events={row['n_trace_events']};"
         f"probe_ns={probe_ns:.1f}")
    save_json("obs_bench.json", results)
    if overhead > OBS_OVERHEAD_MAX:
        raise SystemExit(
            "observability overhead gate failed: telemetry-enabled "
            f"burst wall was {overhead:.4f}x the disabled wall "
            f"(max {OBS_OVERHEAD_MAX}x) — see "
            "benchmarks/results/obs_bench.json")
    for p in exports.values():
        if not os.path.exists(p):
            raise SystemExit(f"observability export missing: {p}")
    return results


if __name__ == "__main__":
    main()
