"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_pruning  -> Fig. 3 / Fig. 4 (auto-pruning curves + resources)
  bench_combined -> Fig. 5 (combined strategies, order sensitivity)
  bench_table2   -> Table II (strategy comparison, resource proxies)
  bench_kernels  -> kernel micro-benchmarks (tuned-vs-default tiles)
  bench_roofline -> §Roofline rows from the dry-run sweeps
  bench_serve    -> serving trajectory (prefill/decode tok/s; scan'd
                    flash-decode vs the seed Python-loop jnp path)
  bench_chaos    -> self-healing smoke (fixed-seed fault injection
                    through the paged engine; token-identity gated)

Usage: ``python benchmarks/run.py [suite ...]`` where suite is any of
pruning/combined/table2/kernels/roofline/serve/chaos (default: all but
chaos, whose row already rides inside serve).  CI runs ``run.py
kernels``, ``run.py serve`` and ``run.py chaos`` as the smoke suites;
the kernel autotuner persists its tile cache at $REPRO_AUTOTUNE_CACHE
so warm runs skip the tile search.
"""
import sys


def main(argv: list[str] | None = None) -> None:
    if "benchmarks" not in sys.modules:
        sys.path.insert(0, __file__.rsplit("/", 2)[0])
    from benchmarks import (bench_chaos, bench_combined, bench_kernels,
                            bench_pruning, bench_roofline, bench_serve,
                            bench_table2)
    suites = {"pruning": bench_pruning, "combined": bench_combined,
              "table2": bench_table2, "kernels": bench_kernels,
              "roofline": bench_roofline, "serve": bench_serve,
              "chaos": bench_chaos}
    # the chaos row already rides inside the serve suite: running both by
    # default would pay for the engine build twice
    picked = argv if argv else [s for s in suites if s != "chaos"]
    unknown = [s for s in picked if s not in suites]
    if unknown:
        raise SystemExit(f"unknown suite(s) {unknown}; have {list(suites)}")
    print("name,us_per_call,derived")
    for s in picked:
        suites[s].main()


if __name__ == '__main__':
    main(sys.argv[1:])
