"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_pruning  -> Fig. 3 / Fig. 4 (auto-pruning curves + resources)
  bench_combined -> Fig. 5 (combined strategies, order sensitivity)
  bench_table2   -> Table II (strategy comparison, resource proxies)
  bench_kernels  -> kernel micro-benchmarks (structural savings)
  bench_roofline -> §Roofline rows from the dry-run sweeps
"""
import sys


def main() -> None:
    if "benchmarks" not in sys.modules:
        sys.path.insert(0, __file__.rsplit("/", 2)[0])
    from benchmarks import (bench_combined, bench_kernels, bench_pruning,
                            bench_roofline, bench_table2)
    print("name,us_per_call,derived")
    bench_pruning.main()
    bench_combined.main()
    bench_table2.main()
    bench_kernels.main()
    bench_roofline.main()


if __name__ == '__main__':
    main()
