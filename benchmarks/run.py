"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_pruning  -> Fig. 3 / Fig. 4 (auto-pruning curves + resources)
  bench_combined -> Fig. 5 (combined strategies, order sensitivity)
  bench_table2   -> Table II (strategy comparison, resource proxies)
  bench_kernels  -> kernel micro-benchmarks (tuned-vs-default tiles)
  bench_roofline -> §Roofline rows from the dry-run sweeps
  bench_serve    -> serving trajectory (prefill/decode tok/s; scan'd
                    flash-decode vs the seed Python-loop jnp path)
  bench_serveflow-> T→V design flow (TUNE → SERVE staged plan search;
                    searched plan gated >= the hand-assembled default)
  bench_chaos    -> self-healing smoke (fixed-seed fault injection
                    through the paged engine; token-identity gated)
  bench_cluster  -> replicated-serving smoke (replica crash mid-burst
                    through the 3-replica front door; failover gated)
  bench_restart  -> durable-serving smoke (child process killed by a
                    seeded crash mid-burst; cold journal recovery gated
                    bit-identical, torn-tail tolerant, zero leaks)
  bench_obs      -> observability overhead smoke (telemetry-enabled vs
                    disabled burst wall gated within 3%; Prometheus +
                    JSONL exports written as CI artifacts)

Usage: ``python benchmarks/run.py [suite ...]`` where suite is any of
the names below (default: all but chaos, cluster and restart, whose
engine rows would otherwise be paid for twice).  ``run.py --list``
prints the available suites.  CI runs ``run.py kernels``, ``run.py
serve``, ``run.py chaos``, ``run.py cluster`` and ``run.py restart`` as
the smoke suites; the kernel autotuner persists its tile cache at
$REPRO_AUTOTUNE_CACHE so warm runs skip the tile search.
"""
import sys

# suite -> (module attr on benchmarks package, one-line description)
SUITES = {
    "pruning": ("bench_pruning",
                "Fig. 3/4 auto-pruning curves and resource proxies"),
    "combined": ("bench_combined",
                 "Fig. 5 combined strategies and order sensitivity"),
    "table2": ("bench_table2",
               "Table II strategy comparison with resource proxies"),
    "kernels": ("bench_kernels",
                "kernel micro-benchmarks, tuned vs default tiles"),
    "roofline": ("bench_roofline",
                 "roofline rows from the dry-run sweeps"),
    "serve": ("bench_serve",
              "paged serving engine: throughput, load, tenants, chaos"),
    "serveflow": ("bench_serveflow",
                  "T→V design flow: staged ServingPlan search, gated "
                  "searched>=default, emits the deployable plan JSON"),
    "chaos": ("bench_chaos",
              "self-healing smoke: fixed-seed faults, token-identity "
              "gated, boundary invariant audit armed"),
    "cluster": ("bench_cluster",
                "replicated serving: replica crash mid-burst, failover "
                "and zero-leak gated, affinity reported"),
    "restart": ("bench_restart",
                "durable serving: child process crash mid-burst, cold "
                "journal recovery gated bit-identical-or-dead-letter, "
                "torn tail tolerated, zero leaked pages/images"),
    "obs": ("bench_obs",
            "observability overhead: telemetry-enabled vs disabled "
            "burst wall gated within 3%, exports written as artifacts"),
}
# these rows already ride inside (or duplicate the engine build of) the
# serve suite: running them by default would pay for the build twice.
# serveflow re-runs TUNE + engine builds as part of the flow under test,
# so it is likewise its own CI step rather than a default rider.
NOT_IN_DEFAULT = ("chaos", "cluster", "serveflow", "restart", "obs")


def _suite_listing() -> str:
    return "\n".join(f"  {name:<9} {desc}"
                     for name, (_, desc) in SUITES.items())


def main(argv: list[str] | None = None) -> None:
    if argv and any(a in ("--list", "-l") for a in argv):
        print("available suites:")
        print(_suite_listing())
        return
    if "benchmarks" not in sys.modules:
        sys.path.insert(0, __file__.rsplit("/", 2)[0])
    import benchmarks
    import importlib
    picked = argv if argv else [s for s in SUITES
                                if s not in NOT_IN_DEFAULT]
    unknown = [s for s in picked if s not in SUITES]
    if unknown:
        raise SystemExit(f"unknown suite(s) {unknown}; available:\n"
                         f"{_suite_listing()}")
    print("name,us_per_call,derived")
    for s in picked:
        mod = importlib.import_module(f"benchmarks.{SUITES[s][0]}")
        mod.main()


if __name__ == '__main__':
    main(sys.argv[1:])
