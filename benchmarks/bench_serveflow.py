"""SERVE design-flow smoke: ``T → V`` end-to-end on a smoke profile.

Runs ``serve_strategy`` (MODEL-GEN → TUNE → SERVE) on the paged-eligible
smoke arch: TUNE persists its tile winners to the autotune cache, SERVE
resolves the default :class:`~repro.serving.plan.ServingPlan` from that
same cache and staged-searches the candidate grid on a smoke-sized
:class:`~repro.serving.traffic.TrafficProfile`.  Three gates:

- **searched >= default** — the emitted plan's stage-2 objective must be
  at least the hand-assembled default plan's on the same profile (the
  staged search pins the default into stage 2 precisely so this
  comparison is measured, not assumed);
- **pruning did its job** — stage-2 replays cover at most half of the
  candidate grid (the whole point of the cheap stage-1 feature pass);
- **the artifact deploys bit-exactly** — the winning plan JSON
  round-trips through ``ServingPlan.from_dict`` unchanged, and an engine
  built with ``PagedServingEngine.from_plan`` carries exactly the
  searched cache config.

The winning plan lands in ``benchmarks/results/serving_plan.json`` (the
deployable artifact CI uploads) next to the ``serveflow_bench.json``
row set.
"""

from __future__ import annotations

import json
import os
import time

import jax

try:
    from benchmarks.common import RESULTS_DIR, emit, save_json
except ImportError:
    from common import RESULTS_DIR, emit, save_json

FLOW_ARCH = "qwen2-7b"          # the paged-eligible smoke shape
FLOW_SLOTS = 4


def main():
    from repro.core.strategies import run, serve_strategy
    from repro.serving.engine import PagedServingEngine
    from repro.serving.plan import ServingPlan
    from repro.serving.traffic import TrafficProfile

    profile = TrafficProfile(name="serveflow_smoke", n_requests=6,
                             prompt_len=32, max_new_tokens=8,
                             prefix_share=0.25, seed=11)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    plan_path = os.path.join(RESULTS_DIR, "serving_plan.json")
    flow = serve_strategy(
        FLOW_ARCH,
        model_params={"smoke": True, "train_en": False},
        # touch only the serving-path kernels, few trials: the flow
        # smoke measures the cross-stage wiring, not tuning quality
        tune_params={"max_problems": 3, "max_trials": 4, "iters": 1},
        serve_params={"profile": profile.to_dict(), "slots": FLOW_SLOTS,
                      "artifact_path": plan_path})
    t0 = time.perf_counter()
    meta = run(flow)
    wall = time.perf_counter() - t0
    res = meta.get("serve.result")

    plan = ServingPlan.from_dict(res["plan"])
    # bit-exact deployability: JSON round-trip is the identity, and an
    # engine built from the loaded artifact carries the searched config
    roundtrip_exact = plan == ServingPlan.from_dict(
        json.loads(json.dumps(plan.to_dict())))
    handle = [m for m in meta.models() if "+V" in m.name][0]
    model = handle.payload.model
    engine = PagedServingEngine.from_plan(model, plan)
    deploy_exact = engine.pcfg == plan.cache \
        and engine.plan == plan \
        and engine.prefill_mode == plan.prefill_mode

    searched, default = res["objective"], res["default_objective"]
    pruned_half = res["n_stage2"] * 2 <= res["n_candidates"]
    row = {
        "backend": jax.default_backend(), "t": time.time(),
        "arch": FLOW_ARCH, "profile": res["profile"],
        "wall_s": wall,
        "objective_tok_s": searched,
        "default_objective_tok_s": default,
        "n_candidates": res["n_candidates"],
        "n_stage2": res["n_stage2"],
        "n_pruned": res["n_pruned"],
        "plan": res["plan"],
        "plan_provenance": res["plan"]["provenance"],
        "verdict": {
            "searched_ge_default": searched >= default,
            "stage2_at_most_half": pruned_half,
            "roundtrip_exact": roundtrip_exact,
            "deploy_exact": deploy_exact,
        },
    }
    emit("serveflow_smoke", wall * 1e6,
         f"obj_tok_s={searched:.1f};vs_default="
         f"{searched / max(default, 1e-9):.2f}x;"
         f"stage2={res['n_stage2']}/{res['n_candidates']};"
         f"page_size={res['plan']['cache']['page_size']};"
         f"segment_len={res['plan']['cache']['segment_len']}")
    save_json("serveflow_bench.json", row)

    v = row["verdict"]
    if not v["searched_ge_default"]:
        raise SystemExit(
            "serveflow: searched plan scored below the hand-assembled "
            f"default on {profile.name} ({searched:.1f} < {default:.1f} "
            "tok/s) — the staged search must never emit a plan worse "
            "than its own stage-2 baseline")
    if not v["stage2_at_most_half"]:
        raise SystemExit(
            f"serveflow: stage 2 replayed {res['n_stage2']} of "
            f"{res['n_candidates']} candidates — stage-1 feature "
            "pruning must skip at least half the grid")
    if not (v["roundtrip_exact"] and v["deploy_exact"]):
        raise SystemExit(
            "serveflow: winning ServingPlan JSON did not reproduce the "
            "searched configuration bit-exactly through "
            "from_dict/from_plan (see serveflow_bench.json)")
    return row


if __name__ == "__main__":
    main()
